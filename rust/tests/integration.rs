//! Integration tests: the distributed coordinator against independent
//! witnesses — the bulk-synchronous baseline (same substrate, different
//! schedule) and closed-form invariants. The PJRT/monolithic-artifact
//! cross-check lives in `runtime_xla.rs` (it needs `make artifacts`).

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{baseline, DistributedMoE, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::stats::max_abs_diff;

fn setup(preset: &str, seed: u64) -> (Config, Arc<ModelParams>, Arc<dyn ComputeBackend>, Vec<Vec<f32>>) {
    let cfg = Config::preset(preset).unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, seed));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
    (cfg, params, backend, inputs)
}

#[test]
fn fused_forward_matches_bulk_sync_baseline() {
    let (cfg, params, backend, inputs) = setup("tiny", 42);
    let moe =
        DistributedMoE::new(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)
            .unwrap();
    let flash = moe.forward(&inputs).unwrap();
    let base = baseline::forward_sequential(&cfg, &params, &backend, &inputs).unwrap();
    for (f, b) in flash.outputs.iter().zip(&base.outputs) {
        assert!(max_abs_diff(f, b) < 1e-4, "flash vs baseline diverged");
    }
}

#[test]
fn split_mode_matches_fused_mode() {
    let (cfg, params, backend, inputs) = setup("tiny", 7);
    let fused =
        DistributedMoE::new(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)
            .unwrap()
            .forward(&inputs)
            .unwrap();
    let split = DistributedMoE::new(cfg, params, backend, TaskGraphMode::Split)
        .unwrap()
        .forward(&inputs)
        .unwrap();
    for (f, s) in fused.outputs.iter().zip(&split.outputs) {
        assert!(max_abs_diff(f, s) < 1e-3, "split task graph diverged from fused");
    }
    // split mode does real tile-granular GEMM work
    let gemm_tasks: u32 = split.metrics.ranks.iter().map(|r| r.gemm_tasks).sum();
    assert!(gemm_tasks > 0, "split mode must run Gemm0/Gemm1 tasks");
}

#[test]
fn forward_is_deterministic_across_runs() {
    let (cfg, params, backend, inputs) = setup("tiny", 9);
    let moe = DistributedMoE::new(cfg, params, backend, TaskGraphMode::Fused).unwrap();
    let a = moe.forward(&inputs).unwrap();
    let b = moe.forward(&inputs).unwrap();
    for (x, y) in a.outputs.iter().zip(&b.outputs) {
        // combine-order nondeterminism only permutes f32 additions of the
        // same k<=2 terms per token; outputs must match to tight tolerance
        assert!(max_abs_diff(x, y) < 1e-5);
    }
}

#[test]
fn repeated_passes_reuse_heap_correctly() {
    // stale flags/data from pass N must not leak into pass N+1
    let (cfg, params, backend, _) = setup("tiny", 11);
    let moe =
        DistributedMoE::new(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)
            .unwrap();
    for seed in [1u64, 2, 3] {
        let inputs: Vec<Vec<f32>> =
            (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, seed, r)).collect();
        let flash = moe.forward(&inputs).unwrap();
        let base = baseline::forward_sequential(&cfg, &params, &backend, &inputs).unwrap();
        for (f, b) in flash.outputs.iter().zip(&base.outputs) {
            assert!(max_abs_diff(f, b) < 1e-4, "pass with seed {seed} diverged");
        }
    }
}

#[test]
fn payload_efficiency_beats_padded_baseline() {
    let (cfg, params, backend, inputs) = setup("default", 5);
    let moe = DistributedMoE::new(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)
        .unwrap();
    let flash = moe.forward(&inputs).unwrap();
    let base = baseline::forward_sequential(&cfg, &params, &backend, &inputs).unwrap();
    let flash_rows: usize = flash.metrics.ranks.iter().map(|r| r.sent_rows).sum();
    assert!(
        flash_rows < base.metrics.sent_rows,
        "payload-efficient dispatch ({flash_rows}) must ship fewer rows than padded ({})",
        base.metrics.sent_rows
    );
    // launch accounting: flash is one persistent kernel per rank
    assert!(base.metrics.launches > 10 * cfg.system.ranks);
}

#[test]
fn metrics_are_consistent() {
    let (cfg, params, backend, inputs) = setup("tiny", 13);
    let moe = DistributedMoE::new(cfg.clone(), params, backend, TaskGraphMode::Fused).unwrap();
    let res = moe.forward(&inputs).unwrap();
    let m = &res.metrics;
    assert_eq!(m.ranks.len(), cfg.system.ranks);
    let total_sent: usize = m.ranks.iter().map(|r| r.tiles_sent).sum();
    let total_ffn: u32 = m.ranks.iter().map(|r| r.ffn_tasks).sum();
    let total_combine: u32 = m.ranks.iter().map(|r| r.combine_tasks).sum();
    // every dispatched tile is FFN'd once and combined once
    assert_eq!(total_sent as u32, total_ffn);
    assert_eq!(total_sent as u32, total_combine);
    for r in &m.ranks {
        assert!(r.utilization() >= 0.0 && r.utilization() <= 1.0);
        assert!(r.wall_secs > 0.0);
    }
    // every routed (non-dropped) pair contributed output rows
    let kept: usize = m.ranks.iter().map(|r| r.sent_rows).sum();
    let dropped: usize = m.ranks.iter().map(|r| r.dropped).sum();
    assert_eq!(kept + dropped, cfg.system.s_total() * cfg.model.k);
}

#[test]
fn tight_capacity_drops_consistently() {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.set("capacity_factor", "0.25").unwrap(); // tighten capacity
    let params = Arc::new(ModelParams::generate(&cfg, 3));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 3, r)).collect();
    let moe = DistributedMoE::new(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)
        .unwrap();
    let flash = moe.forward(&inputs).unwrap();
    assert!(flash.metrics.total_dropped() > 0, "tight capacity must drop");
    // drops must match the bulk-sync witness exactly (same gate contract)
    let base = baseline::forward_sequential(&cfg, &params, &backend, &inputs).unwrap();
    for (f, b) in flash.outputs.iter().zip(&base.outputs) {
        assert!(max_abs_diff(f, b) < 1e-4);
    }
}

#[test]
fn single_rank_degenerates_cleanly() {
    let mut cfg = Config::preset("tiny").unwrap();
    cfg.set("ranks", "1").unwrap();
    cfg.set("nodes", "1").unwrap();
    cfg.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, 1));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let inputs = vec![generate_tokens(&cfg, 1, 0)];
    let moe = DistributedMoE::new(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)
        .unwrap();
    let flash = moe.forward(&inputs).unwrap();
    let base = baseline::forward_sequential(&cfg, &params, &backend, &inputs).unwrap();
    assert!(max_abs_diff(&flash.outputs[0], &base.outputs[0]) < 1e-4);
}

#[test]
fn wrong_input_arity_is_rejected() {
    let (cfg, params, backend, mut inputs) = setup("tiny", 2);
    let moe = DistributedMoE::new(cfg, params, backend, TaskGraphMode::Fused).unwrap();
    inputs.pop();
    assert!(moe.forward(&inputs).is_err());
}

#[test]
fn processor_count_does_not_change_numerics() {
    let (cfg, params, backend, inputs) = setup("tiny", 21);
    let mut cfg1 = cfg.clone();
    cfg1.set("processors", "1").unwrap();
    let mut cfg8 = cfg;
    cfg8.set("processors", "8").unwrap();
    let a = DistributedMoE::new(cfg1, params.clone(), backend.clone(), TaskGraphMode::Fused)
        .unwrap()
        .forward(&inputs)
        .unwrap();
    let b = DistributedMoE::new(cfg8, params, backend, TaskGraphMode::Split)
        .unwrap()
        .forward(&inputs)
        .unwrap();
    for (x, y) in a.outputs.iter().zip(&b.outputs) {
        assert!(max_abs_diff(x, y) < 1e-3);
    }
}
