//! Serving example: the request-level front door.
//!
//! `MoeService` is the deployment shape — a resident continuous batcher
//! (bounded admission queue, `BatchPolicy` coalescing, round-robin row
//! packing into variable-shape engine passes, scatter-gather back per
//! request) over the persistent engine, launched exactly once. Synthetic
//! clients drive open-loop Poisson traffic of variable-length requests
//! (`workload::ArrivalProcess`); the example reports request latency
//! percentiles, queue time, batch fill and throughput, spot-checks
//! request outputs against the dense per-token reference (dropless
//! routing makes results independent of co-batching), and asserts the
//! single-launch contract.
//!
//!     cargo run --release --example serve
//!
//! Env knobs: `REQUESTS` (default 48), `RATE` req/s (default 400).

use std::sync::Arc;
use std::time::{Duration, Instant};

use flashdmoe::config::Config;
use flashdmoe::coordinator::{BatchPolicy, MoeService, RequestOpts, TaskGraphMode};
use flashdmoe::expert::ModelParams;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::check::dense_reference_moe;
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::{fmt_time, max_abs_diff, summarize, Table};
use flashdmoe::workload::ArrivalProcess;

fn main() -> anyhow::Result<()> {
    let n_requests: usize =
        std::env::var("REQUESTS").ok().and_then(|v| v.parse().ok()).unwrap_or(48);
    let rate: f64 = std::env::var("RATE").ok().and_then(|v| v.parse().ok()).unwrap_or(400.0);

    let mut cfg = Config::preset("tiny")?;
    // dropless: a request's output never depends on what shares its pass
    cfg.set("routing_policy", "dropless")?;
    let params = Arc::new(ModelParams::generate(&cfg, 42));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));

    // launch once — every request below is served by these resident actors
    let policy = BatchPolicy::from_config(&cfg);
    println!(
        "serving: max_tokens={} per pass ({} ranks x {}), max_delay={:?}, queue={} requests",
        policy.max_tokens,
        cfg.system.ranks,
        cfg.system.s_rank,
        policy.max_delay,
        policy.queue_requests
    );
    let service =
        MoeService::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused, policy)?;

    // Open-loop Poisson arrivals of variable-length requests, the same
    // drive shape `harness::serving_bench` measures headlessly — this
    // example deliberately stays on the raw enqueue/wait API (that's
    // what it demonstrates) and adds dense-reference spot checks.
    let h = cfg.model.h;
    let mut rng = Rng::new(7);
    let arrivals = ArrivalProcess::Poisson { rate }.arrivals(
        n_requests,
        (8, (cfg.system.s_rank / 2).max(8)),
        &mut rng,
    )?;

    let t0 = Instant::now();
    let mut pending = Vec::with_capacity(n_requests);
    for a in &arrivals {
        if let Some(wait) = Duration::from_secs_f64(a.at).checked_sub(t0.elapsed()) {
            std::thread::sleep(wait);
        }
        let tokens = rng.normal_vec(a.tokens * h, 1.0);
        let handle = service
            .enqueue(tokens.clone(), RequestOpts::default())
            .map_err(|e| anyhow::anyhow!("enqueue: {e}"))?;
        pending.push((tokens, handle));
    }

    let mut latencies = Vec::new();
    let mut queue_times = Vec::new();
    let mut served_tokens = 0usize;
    let mut checked = 0usize;
    for (i, (tokens, handle)) in pending.into_iter().enumerate() {
        let res = handle.wait()?;
        anyhow::ensure!(res.tokens.len() == tokens.len(), "request {i}: wrong output shape");
        served_tokens += res.rows;
        latencies.push(res.latency_secs);
        queue_times.push(res.queue_secs);
        // spot-check against the dense per-token reference
        if i % 8 == 0 {
            let want = dense_reference_moe(&cfg, &params, &tokens);
            let diff = max_abs_diff(&res.tokens, &want);
            anyhow::ensure!(diff < 1e-5, "request {i}: diverged from dense reference by {diff}");
            checked += 1;
        }
    }
    let wall = t0.elapsed().as_secs_f64();
    let report = service.shutdown();

    let lat = summarize(&latencies);
    let qt = summarize(&queue_times);
    let mut t = Table::new(&["metric", "value"]);
    t.row(&["requests".into(), n_requests.to_string()]);
    t.row(&["arrival rate".into(), format!("{rate:.0} req/s (Poisson)")]);
    t.row(&["tokens served".into(), served_tokens.to_string()]);
    t.row(&["latency p50".into(), fmt_time(lat.p50)]);
    t.row(&["latency p95".into(), fmt_time(lat.p95)]);
    t.row(&["latency p99".into(), fmt_time(lat.p99)]);
    t.row(&["queue-time p50".into(), fmt_time(qt.p50)]);
    t.row(&["batch fill".into(), format!("{:.1}%", report.service.mean_batch_fill() * 100.0)]);
    t.row(&["peak queue depth".into(), report.service.max_queue_depth.to_string()]);
    t.row(&[
        "engine passes".into(),
        format!("{} ({} launch)", report.service.passes, report.engine.launches),
    ]);
    t.row(&["throughput".into(), format!("{:.0} tokens/s", served_tokens as f64 / wall)]);
    t.row(&["dense-reference spot checks".into(), format!("{checked} passed @1e-5")]);
    println!("{}", t.render());

    assert_eq!(report.service.requests_served, n_requests as u64, "every request served");
    assert_eq!(report.engine.launches, 1, "one launch for the service lifetime");
    assert!(report.service.passes >= 1);
    println!("serve OK");
    Ok(())
}
