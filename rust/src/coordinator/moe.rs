//! The public `DistributedMoE` operator: the API a downstream framework
//! embeds. One call = one fused MoE layer forward across all ranks.

use std::sync::Arc;

use anyhow::Result;

use crate::config::Config;
use crate::expert::ModelParams;
use crate::fabric::SymmetricHeap;
use crate::layout::LayoutDims;
use crate::runtime::ComputeBackend;

use super::metrics::PassMetrics;
use super::rank::{run_rank, ClusterShared, RankOutput};

pub use super::rank::TaskGraphMode;

/// Result of one distributed forward pass.
pub struct ForwardResult {
    /// Per-rank output matrices (S_r, H), row-major.
    pub outputs: Vec<Vec<f32>>,
    pub metrics: PassMetrics,
}

/// The distributed MoE operator. Construct once (weights uploaded /
/// sliced, symmetric heap allocated), call [`forward`] per layer pass.
///
/// Ranks are threads in this in-process fabric; every data movement goes
/// through the write-conflict-free symmetric heap exactly as the paper's
/// kernel moves tiles through NVSHMEM symmetric memory.
pub struct DistributedMoE {
    cfg: Config,
    params: Arc<ModelParams>,
    heap: Arc<SymmetricHeap>,
    backend: Arc<dyn ComputeBackend>,
    mode: TaskGraphMode,
}

impl DistributedMoE {
    pub fn new(
        cfg: Config,
        params: Arc<ModelParams>,
        backend: Arc<dyn ComputeBackend>,
        mode: TaskGraphMode,
    ) -> Result<Self> {
        cfg.validate()?;
        let dims = LayoutDims::from_config(&cfg);
        let heap = Arc::new(SymmetricHeap::new(dims, cfg.system.ranks_per_node()));
        Ok(Self { cfg, params, heap, backend, mode })
    }

    pub fn config(&self) -> &Config {
        &self.cfg
    }

    pub fn params(&self) -> &ModelParams {
        &self.params
    }

    /// Bytes of the symmetric tensor L per rank (Table 3's Size(L)).
    pub fn heap_bytes_per_rank(&self) -> f64 {
        LayoutDims::from_config(&self.cfg).bytes(4.0)
    }

    /// One fused forward pass. `inputs[r]` is rank r's (S_r, H) tokens.
    pub fn forward(&self, inputs: &[Vec<f32>]) -> Result<ForwardResult> {
        anyhow::ensure!(
            inputs.len() == self.cfg.system.ranks,
            "need {} rank inputs, got {}",
            self.cfg.system.ranks,
            inputs.len()
        );
        self.heap.reset();
        let shared = ClusterShared::new(
            self.cfg.clone(),
            self.params.clone(),
            self.heap.clone(),
            self.backend.clone(),
            self.mode,
        );
        let t0 = std::time::Instant::now();
        let rank_outputs: Vec<RankOutput> = std::thread::scope(|scope| {
            let handles: Vec<_> = inputs
                .iter()
                .enumerate()
                .map(|(r, a)| {
                    let shared = &shared;
                    scope.spawn(move || run_rank(shared, r, a))
                })
                .collect();
            handles
                .into_iter()
                .map(|h| h.join().expect("rank thread panicked"))
                .collect::<Result<Vec<_>>>()
        })?;
        let wall = t0.elapsed().as_secs_f64();
        let mut outputs = Vec::with_capacity(rank_outputs.len());
        let mut metrics = PassMetrics { wall_secs: wall, ranks: Vec::new() };
        for ro in rank_outputs {
            outputs.push(ro.out);
            metrics.ranks.push(ro.metrics);
        }
        Ok(ForwardResult { outputs, metrics })
    }
}
