//! Optimizers over [`ModelParams`]: plain/momentum SGD and Adam, both
//! stepping the fixed tensor traversal shared with [`GradStore`] so the
//! update order (and therefore every parameter bit) is deterministic.
//!
//! Both optimizers support *decoupled* weight decay (the AdamW recipe:
//! `p ← p·(1 − lr·λ) − lr·update(g)`), configured via
//! [`with_weight_decay`](Optimizer::with_weight_decay) or the
//! `weight_decay` config knob — the decay term never flows through the
//! momentum/moment state, so Adam's adaptive scaling cannot cancel it.
//! [`set_lr`](Optimizer::set_lr) lets `Trainer` drive a
//! [`LrSchedule`](crate::config::LrSchedule) over updates.

use crate::expert::ModelParams;

use super::grad::{param_tensors_mut, GradStore};

/// First-order optimizer. State tensors (`vel`, `m`, `v`) are lazily
/// allocated [`GradStore`]s on the first step, so constructing an
/// optimizer is free and shape-agnostic.
#[derive(Clone, Debug)]
pub enum Optimizer {
    Sgd {
        lr: f32,
        /// 0.0 = plain SGD; otherwise classical momentum.
        momentum: f32,
        /// Decoupled weight-decay coefficient (0 disables).
        weight_decay: f32,
        vel: Option<GradStore>,
    },
    Adam {
        lr: f32,
        beta1: f32,
        beta2: f32,
        eps: f32,
        /// Decoupled (AdamW-style) weight-decay coefficient (0 disables).
        weight_decay: f32,
        /// Step count for bias correction (increments per `step`).
        t: u64,
        m: Option<GradStore>,
        v: Option<GradStore>,
    },
}

impl Optimizer {
    pub fn sgd(lr: f32) -> Self {
        Optimizer::Sgd { lr, momentum: 0.0, weight_decay: 0.0, vel: None }
    }

    pub fn sgd_momentum(lr: f32, momentum: f32) -> Self {
        Optimizer::Sgd { lr, momentum, weight_decay: 0.0, vel: None }
    }

    /// Adam with the conventional defaults (β1=0.9, β2=0.999, ε=1e-8).
    pub fn adam(lr: f32) -> Self {
        Optimizer::Adam {
            lr,
            beta1: 0.9,
            beta2: 0.999,
            eps: 1e-8,
            weight_decay: 0.0,
            t: 0,
            m: None,
            v: None,
        }
    }

    /// Builder: set the decoupled weight-decay coefficient.
    pub fn with_weight_decay(mut self, wd: f32) -> Self {
        match &mut self {
            Optimizer::Sgd { weight_decay, .. } | Optimizer::Adam { weight_decay, .. } => {
                *weight_decay = wd
            }
        }
        self
    }

    /// Construct from the config's training knobs (`optimizer`, `lr`,
    /// `weight_decay`).
    pub fn from_config(tc: &crate::config::TrainConfig) -> Self {
        let base = match tc.optimizer {
            crate::config::OptimizerKind::Sgd => Optimizer::sgd(tc.lr),
            crate::config::OptimizerKind::Adam => Optimizer::adam(tc.lr),
        };
        base.with_weight_decay(tc.weight_decay)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Optimizer::Sgd { .. } => "sgd",
            Optimizer::Adam { .. } => "adam",
        }
    }

    pub fn lr(&self) -> f32 {
        match self {
            Optimizer::Sgd { lr, .. } | Optimizer::Adam { lr, .. } => *lr,
        }
    }

    /// Override the learning rate (a schedule hook: `Trainer` calls this
    /// with `base_lr × LrSchedule::factor(update)` before each step;
    /// momentum/moment state is untouched).
    pub fn set_lr(&mut self, new_lr: f32) {
        match self {
            Optimizer::Sgd { lr, .. } | Optimizer::Adam { lr, .. } => *lr = new_lr,
        }
    }

    pub fn weight_decay(&self) -> f32 {
        match self {
            Optimizer::Sgd { weight_decay, .. } | Optimizer::Adam { weight_decay, .. } => {
                *weight_decay
            }
        }
    }

    /// Apply one update: `params -= f(grads)`. Panics (debug) on shape
    /// mismatch; tensors are zipped in the shared traversal order.
    /// A non-zero `weight_decay` first shrinks every parameter by
    /// `lr·λ·θ` (decoupled: the gradient transform below never sees it).
    pub fn step(&mut self, params: &mut ModelParams, grads: &GradStore) {
        let (lr_now, wd) = (self.lr(), self.weight_decay());
        if wd != 0.0 {
            let shrink = 1.0 - lr_now * wd;
            for p in param_tensors_mut(params) {
                for pv in p.iter_mut() {
                    *pv *= shrink;
                }
            }
        }
        match self {
            Optimizer::Sgd { lr, momentum, vel, .. } => {
                let lr = *lr;
                let mu = *momentum;
                if mu == 0.0 {
                    for (p, g) in param_tensors_mut(params).into_iter().zip(grads.tensors()) {
                        for (pv, &gv) in p.iter_mut().zip(g) {
                            *pv -= lr * gv;
                        }
                    }
                } else {
                    let vel = vel.get_or_insert_with(|| GradStore::zeros_like(params));
                    for ((p, g), v) in param_tensors_mut(params)
                        .into_iter()
                        .zip(grads.tensors())
                        .zip(vel.tensors_mut())
                    {
                        for ((pv, &gv), vv) in p.iter_mut().zip(g).zip(v.iter_mut()) {
                            *vv = mu * *vv + gv;
                            *pv -= lr * *vv;
                        }
                    }
                }
            }
            Optimizer::Adam { lr, beta1, beta2, eps, t, m, v, .. } => {
                let (lr, b1, b2, eps) = (*lr, *beta1, *beta2, *eps);
                *t += 1;
                let bc1 = 1.0 - b1.powi(*t as i32);
                let bc2 = 1.0 - b2.powi(*t as i32);
                let m = m.get_or_insert_with(|| GradStore::zeros_like(params));
                let v = v.get_or_insert_with(|| GradStore::zeros_like(params));
                for (((p, g), mt), vt) in param_tensors_mut(params)
                    .into_iter()
                    .zip(grads.tensors())
                    .zip(m.tensors_mut())
                    .zip(v.tensors_mut())
                {
                    for (((pv, &gv), mv), vv) in
                        p.iter_mut().zip(g).zip(mt.iter_mut()).zip(vt.iter_mut())
                    {
                        *mv = b1 * *mv + (1.0 - b1) * gv;
                        *vv = b2 * *vv + (1.0 - b2) * gv * gv;
                        let mhat = *mv / bc1;
                        let vhat = *vv / bc2;
                        *pv -= lr * mhat / (vhat.sqrt() + eps);
                    }
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_params() -> ModelParams {
        let cfg = crate::config::Config::preset("tiny").unwrap();
        ModelParams::generate(&cfg, 7)
    }

    #[test]
    fn sgd_moves_against_the_gradient() {
        let mut params = tiny_params();
        let before = params.wg[0];
        let mut g = GradStore::zeros_like(&params);
        g.wg[0] = 2.0;
        let mut opt = Optimizer::sgd(0.5);
        opt.step(&mut params, &g);
        assert_eq!(params.wg[0], before - 1.0);
    }

    #[test]
    fn momentum_accumulates_velocity() {
        let mut params = tiny_params();
        let before = params.experts[0].b1[0];
        let mut g = GradStore::zeros_like(&params);
        g.experts[0].b1[0] = 1.0;
        let mut opt = Optimizer::sgd_momentum(0.1, 0.9);
        opt.step(&mut params, &g); // v=1.0, p -= 0.1
        opt.step(&mut params, &g); // v=1.9, p -= 0.19
        let moved = before - params.experts[0].b1[0];
        assert!((moved - 0.29).abs() < 1e-6, "momentum compounding, moved {moved}");
    }

    #[test]
    fn adam_first_step_is_lr_sized() {
        // with bias correction, step 1 moves ~lr·sign(g) regardless of |g|
        let mut params = tiny_params();
        let before = params.experts[1].b2[3];
        let mut g = GradStore::zeros_like(&params);
        g.experts[1].b2[3] = 1e-3;
        let mut opt = Optimizer::adam(0.01);
        opt.step(&mut params, &g);
        let moved = before - params.experts[1].b2[3];
        assert!((moved - 0.01).abs() < 1e-4, "bias-corrected first step, moved {moved}");
        assert_eq!(opt.name(), "adam");
        assert_eq!(opt.lr(), 0.01);
    }

    #[test]
    fn zero_grad_is_a_noop_for_sgd() {
        let mut params = tiny_params();
        let snapshot = params.wg.clone();
        let g = GradStore::zeros_like(&params);
        let mut opt = Optimizer::sgd(1.0);
        opt.step(&mut params, &g);
        assert_eq!(params.wg, snapshot);
    }

    #[test]
    fn decoupled_weight_decay_shrinks_params() {
        // zero grad: the only movement is the decoupled p *= (1 - lr·λ)
        let mut params = tiny_params();
        let before = params.wg[0];
        let g = GradStore::zeros_like(&params);
        let mut opt = Optimizer::sgd(0.1).with_weight_decay(0.5);
        assert_eq!(opt.weight_decay(), 0.5);
        opt.step(&mut params, &g);
        assert_eq!(params.wg[0], before * (1.0 - 0.1 * 0.5));
        // Adam with zero grad: moments stay 0, so decay is still the only
        // movement (decoupled — decay never enters the m/v state)
        let mut params = tiny_params();
        let before = params.experts[0].w1[5];
        let mut adam = Optimizer::adam(0.01).with_weight_decay(0.1);
        adam.step(&mut params, &g);
        assert_eq!(params.experts[0].w1[5], before * (1.0 - 0.01 * 0.1));
    }

    #[test]
    fn set_lr_rescales_subsequent_steps() {
        let mut params = tiny_params();
        let before = params.wg[0];
        let mut g = GradStore::zeros_like(&params);
        g.wg[0] = 1.0;
        let mut opt = Optimizer::sgd(0.5);
        opt.set_lr(0.25);
        assert_eq!(opt.lr(), 0.25);
        opt.step(&mut params, &g);
        assert_eq!(params.wg[0], before - 0.25);
    }

    #[test]
    fn from_config_reads_the_training_knobs() {
        let mut cfg = crate::config::Config::preset("tiny").unwrap();
        cfg.set("optimizer", "sgd").unwrap();
        cfg.set("lr", "0.125").unwrap();
        cfg.set("weight_decay", "0.01").unwrap();
        let opt = Optimizer::from_config(&cfg.system.train);
        assert_eq!(opt.name(), "sgd");
        assert_eq!(opt.lr(), 0.125);
        assert_eq!(opt.weight_decay(), 0.01);
    }
}
