//! Configuration system: model shapes, parallelism topology, and the
//! hardware cost model used by the discrete-event simulator.
//!
//! Configs come from presets (matching the paper's testbeds and the AOT
//! manifest presets), from `KEY=VALUE` config files, or from CLI overrides.
//! Everything downstream (gate, layout, coordinator, sim, benches) consumes
//! these structs — there is a single source of shape/capacity math.
//!
//! ## Serving knobs
//!
//! The request-level front end ([`MoeService`]) layers a [`BatchPolicy`]
//! on top of this config; its defaults derive from here
//! ([`BatchPolicy::from_config`]):
//!
//! * `max_tokens` — rows coalesced per engine pass; defaults to
//!   [`SystemConfig::max_batch_tokens`] (`ranks × s_rank`, one full
//!   pass), and may be lowered to trade batch fill for latency.
//! * `max_delay` — how long the oldest queued request waits for
//!   co-travelers before a partially-filled pass is submitted anyway.
//! * `queue_requests` + `on_full` — the bounded admission queue and its
//!   backpressure (`Reject` ⇒ `enqueue` fails fast with `ServiceFull`;
//!   `Block` ⇒ the caller waits for space).
//! * `oversize` — requests larger than `max_tokens` are `Split` across
//!   passes (MoE is per-token, so splitting is result-invariant) or
//!   `Reject`ed.
//! * `priority` — FIFO or priority-ordered admission.
//!
//! ## Wire precision
//!
//! [`WirePrecision`] selects the element format of dispatch/combine
//! payloads crossing the symmetric heap (`wire_precision=f32|f16|bf16`).
//! It is a *wire* knob, not a compute knob: 16-bit settings halve the
//! measured fabric bytes and heap footprint while every GEMM still
//! accumulates in f32. The old `elem_bytes` float knob is a deprecated
//! shim over it (2 → `F16`, 4 → `F32`).
//!
//! ## Multi-node knobs
//!
//! The `crate::transport` subsystem reads its shape and NIC model from
//! here:
//!
//! * `nodes` — how many nodes the `ranks` spread over (even split
//!   enforced); links within a node are NVLink-class, across nodes
//!   NIC-class.
//! * `topology` / `dispatch` = `flat` | `hier`(`archical`) — the
//!   inter-node dispatch schedule ([`DispatchMode`]): direct per-tile
//!   puts vs coalesced per-node transfers through proxy ranks.
//! * `nic_bandwidth` / `nic_latency` — the NIC link parameters
//!   ([`CostModel::inter_bw`] / [`CostModel::inter_lat`]; the spellings
//!   `inter_bw` / `inter_lat` are equivalent).
//! * `nic_buffer` — bytes of per-rank NIC receive buffering; one pass's
//!   inter-node traffic into a rank beyond this fails the pass with a
//!   measured incast-overflow error (Fig 17).
//! * `nic_delay` = `true|false` — inject real `latency + bytes/bw` delay
//!   per NIC transfer into the live engine (calibrated-sim mode).
//!
//! ## Replication knobs
//!
//! [`ReplicationPolicy`] governs hot-expert replication (ROADMAP item 2):
//! the engine tracks an EWMA of per-expert *offered* load across passes
//! and, between passes, installs replicas of the hottest experts into
//! spare expert slots on underloaded ranks
//! (`crate::placement::plan_replication`); the gate then shards those
//! experts' tokens across their serving locations. All knobs flow
//! through [`Config::set`]:
//!
//! * `replicate_top` (alias `top_r`) — how many of the hottest experts
//!   are eligible for replication; `0` (the default) disables the whole
//!   subsystem and also sizes zero replica slots, so static engines pay
//!   no heap/flag overhead.
//! * `replicas` — target serving copies per hot expert, primary
//!   included (so `2` means one replica); clamped to `ranks`.
//! * `replication_hysteresis` — an expert enters replication while its
//!   EWMA load ≥ `hysteresis × mean`, and its replicas are only torn
//!   down below half that, so borderline experts don't flap.
//! * `ewma_alpha` — smoothing factor of the load tracker in `(0, 1]`.
//!
//! ## Fault-tolerance knobs
//!
//! The robustness layer (ROADMAP item 5) is governed from here; the
//! deterministic injection schedule itself lives in [`FaultConfig`] /
//! `crate::fault`, and the recovery machinery in the engine:
//!
//! * `watchdog_secs` — seconds without subscriber progress before a rank
//!   declares the pass wedged and panics (default 120; chaos tests dial
//!   it down so wedge detection runs at test scale).
//! * `retry_limit` — how many times a failed pass is transparently
//!   re-fenced and resubmitted by the engine before the error surfaces
//!   to the caller (default 0: fail fast, the pre-existing behavior).
//!   A transiently-faulted pass retried this way produces bitwise
//!   identical output to a fault-free run.
//! * `fault_seed` / `fault_transient_rate` / `fault_transient_from` /
//!   `fault_transient_until` — seedable transient transfer faults,
//!   decided per (src, dst, pass generation), optionally windowed to a
//!   range of pass generations (`until = 0` means open-ended). A retried
//!   pass runs under a fresh generation and re-rolls.
//! * `fault_kill_rank` (`none` to clear) + `fault_kill_epoch` — a
//!   permanent rank death: from that pass generation on, every transfer
//!   touching the rank fails. The engine responds with an epoch-fenced
//!   degraded `Placement` swap (replicas keep serving the dead rank's
//!   replicated experts; un-replicated ones are accounted unavailable).
//! * `fault_delay_rate` + `fault_delay_us` — injected NIC delay spikes
//!   (per-transfer, same deterministic per-(src, dst, gen) decision).
//!
//! ## Multi-model residency knobs
//!
//! One persistent engine can host several expert sets at once (the
//! `crate::registry` subsystem; ROADMAP item 5):
//!
//! * `max_models` — how many models the engine reserves heap/flag
//!   capacity for at start (default 1: the single-model layout is
//!   byte-identical to before the knob existed). Every layout table's
//!   expert-slot dimension is multiplied by this, partitioning the
//!   symmetric heap into per-model slot bands; models are then
//!   registered/evicted at epoch-fenced quiet points
//!   (`MoeEngine::register_model` / `evict_model`) without restarting.
//!   All resident models must share this config's architecture
//!   (`h`/`d`/`e`/`k`); re-registering byte-identical weights dedups to
//!   the already-packed cache entry, and LoRA-style deltas
//!   (`MoeEngine::register_delta`) share the base model's packed panels
//!   outright.
//!
//! ## Training-schedule knobs
//!
//! * `weight_decay` — decoupled (AdamW-style) weight decay applied by
//!   `train::Optimizer` at each step (default 0: plain SGD/Adam).
//! * `lr_schedule` = `const` | `step:<every>:<gamma>` |
//!   `cosine:<total>` — learning-rate schedule the `Trainer` evaluates
//!   per optimizer update ([`LrSchedule`]).
//!
//! [`MoeService`]: crate::coordinator::MoeService
//! [`BatchPolicy`]: crate::coordinator::BatchPolicy
//! [`BatchPolicy::from_config`]: crate::coordinator::BatchPolicy::from_config

use anyhow::{bail, Context, Result};

/// Element format of the **wire** — the dispatch and combine payloads
/// crossing the symmetric heap. Payloads are quantized to this width when
/// `SymmetricHeap::put_signal` copies them into the destination inbox and
/// dequantized back to f32 when the consumer reads them (`crate::wire`
/// owns the conversions), so expert GEMMs, gate math and the combine fold
/// always run in f32 — *wire* precision and *compute* precision are
/// separate axes.
///
/// Guarantees by setting:
///
/// * `F32` (default) — the encode/decode pair is a bitwise byte copy;
///   outputs are bit-identical to an engine without the wire subsystem,
///   and all determinism/conformance guarantees hold unchanged.
/// * `Bf16` / `F16` — payload bytes halve (measured, not modeled: the
///   heap's byte counters account at this width). Outputs remain bitwise
///   deterministic across restarts/schedules (round-to-nearest-even is
///   order-free), but match the dense f32 reference only to the format's
///   [`conformance_tol`](WirePrecision::conformance_tol).
///
/// Select per config: `cfg.set("wire_precision", "bf16")` (also `"f16"`,
/// `"f32"`). The legacy float-typed `elem_bytes` knob survives as a
/// deprecation shim: `elem_bytes=2` implies `F16` and `elem_bytes=4`
/// implies `F32` — but only when the requested width actually differs
/// from the configured wire's, so it never downgrades an explicit `Bf16`
/// (other widths only retune the simulator's cost model).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum WirePrecision {
    /// 4-byte f32 wire: bitwise-transparent (the pre-existing contract).
    #[default]
    F32,
    /// 2-byte IEEE binary16 wire: 10 mantissa bits, narrow exponent
    /// (overflows past 65504 saturate to Inf on the wire).
    F16,
    /// 2-byte bfloat16 wire: 7 mantissa bits, full f32 exponent range.
    Bf16,
}

impl WirePrecision {
    /// Bytes per wire scalar.
    pub fn bytes(self) -> usize {
        match self {
            WirePrecision::F32 => 4,
            WirePrecision::F16 | WirePrecision::Bf16 => 2,
        }
    }

    /// Canonical knob spelling (accepted by [`parse`](Self::parse)).
    pub fn name(self) -> &'static str {
        match self {
            WirePrecision::F32 => "f32",
            WirePrecision::F16 => "f16",
            WirePrecision::Bf16 => "bf16",
        }
    }

    /// True for the 16-bit formats (payload narrowing in effect).
    pub fn is_reduced(self) -> bool {
        !matches!(self, WirePrecision::F32)
    }

    /// Parse a CLI/config-file value.
    pub fn parse(s: &str) -> Option<WirePrecision> {
        match s.to_ascii_lowercase().as_str() {
            "f32" | "fp32" | "float32" => Some(WirePrecision::F32),
            "f16" | "fp16" | "half" | "float16" => Some(WirePrecision::F16),
            "bf16" | "bfloat16" => Some(WirePrecision::Bf16),
            _ => None,
        }
    }

    /// Documented conformance tolerance of an engine pass against the
    /// dense f32 per-token reference (`util::check::dense_reference_moe`)
    /// on unit-scale workloads (tokens ~ N(0,1), `INIT_STD` weights).
    /// Both the dispatch and the combine payload are quantized once each,
    /// so the bound is a comfortable multiple of the format's
    /// 2^-(mantissa bits + 1) relative rounding error; `F32` keeps the
    /// exact-path 1e-5 used by the pre-existing conformance suite.
    pub fn conformance_tol(self) -> f32 {
        match self {
            WirePrecision::F32 => 1e-5,
            WirePrecision::F16 => 5e-2,
            WirePrecision::Bf16 => 2.5e-1,
        }
    }
}

/// How dispatch traffic crosses node boundaries (the transport schedule;
/// see `crate::transport` for the fabric it runs on).
///
/// * [`Flat`](DispatchMode::Flat) — every dispatch tile is one direct
///   put to its destination rank, regardless of node locality. Remote
///   tiles each cross the NIC individually, and a token routed to `k`
///   experts on one remote node crosses `k` times.
/// * [`Hierarchical`](DispatchMode::Hierarchical) — the FSMoE-style
///   two-level schedule: all tiles bound for one remote node travel as a
///   single coalesced transfer of the node's *unique* token rows to a
///   proxy rank, which fans the per-tile payloads out intra-node. Fewer,
///   larger NIC transfers and strictly no duplicate rows on the wire;
///   pass outputs are bitwise identical to `Flat` (the proxy hop
///   preserves logical source coordinates, so the announcement tables
///   and the plan-order combine fold are untouched).
///
/// Select per config: `cfg.set("topology", "hier")` (also spelled
/// `dispatch=hierarchical`). Defaults to `Flat`; the `paper_multinode`
/// preset selects `Hierarchical`.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum DispatchMode {
    /// Direct per-tile puts; every remote tile crosses the NIC alone.
    #[default]
    Flat,
    /// Coalesced per-node transfers with intra-node proxy fan-out.
    Hierarchical,
}

impl DispatchMode {
    /// Canonical knob spelling (accepted by [`parse`](Self::parse)).
    pub fn name(self) -> &'static str {
        match self {
            DispatchMode::Flat => "flat",
            DispatchMode::Hierarchical => "hierarchical",
        }
    }

    /// Parse a CLI/config-file value.
    pub fn parse(s: &str) -> Option<DispatchMode> {
        match s.to_ascii_lowercase().as_str() {
            "flat" | "direct" => Some(DispatchMode::Flat),
            "hier" | "hierarchical" | "coalesced" => Some(DispatchMode::Hierarchical),
            _ => None,
        }
    }

    pub fn is_hierarchical(self) -> bool {
        matches!(self, DispatchMode::Hierarchical)
    }
}

/// Hot-expert replication policy (ROADMAP item 2; grounded in "Fast MoE
/// Inference via Predictive Prefetching and Expert Replication",
/// PAPERS.md).
///
/// When [`enabled`](Self::enabled), every rank reserves
/// [`top_r`](Self::top_r) spare *replica slots* next to its owned expert
/// slots (heap regions, signal flags and announcement lanes are sized at
/// engine start exactly like owned slots), and
/// [`MoeEngine::rebalance`](crate::coordinator::MoeEngine::rebalance)
/// may bind a hot expert into such a slot between passes — epoch-fenced,
/// so no in-flight pass ever observes a placement change. The gate's
/// dispatch plan then shards a replicated expert's tokens across its
/// serving locations deterministically (arrival index modulo copy
/// count), which keeps outputs bitwise identical to static placement.
///
/// Disabled by default (`top_r == 0`): the static block placement of
/// `Config::owner_of` with zero slot overhead.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReplicationPolicy {
    /// How many of the hottest experts may hold replicas (0 disables);
    /// also the number of spare replica slots reserved per rank.
    pub top_r: usize,
    /// Target serving copies per hot expert, primary included; values
    /// below 2 make replication a no-op, values above `ranks` clamp.
    pub replicas: usize,
    /// Enter threshold multiplier: replicate expert `e` while its EWMA
    /// offered load ≥ `hysteresis × mean`; tear down only below half
    /// that (the hysteresis band that prevents flapping).
    pub hysteresis: f64,
    /// EWMA smoothing factor in `(0, 1]`: the weight of the newest
    /// pass's observation.
    pub ewma_alpha: f64,
}

impl Default for ReplicationPolicy {
    fn default() -> Self {
        Self { top_r: 0, replicas: 2, hysteresis: 1.5, ewma_alpha: 0.3 }
    }
}

impl ReplicationPolicy {
    /// True when the policy can ever install a replica.
    pub fn enabled(&self) -> bool {
        self.top_r > 0 && self.replicas >= 2
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.hysteresis.is_finite() && self.hysteresis >= 1.0) {
            bail!("replication_hysteresis must be finite and >= 1.0, got {}", self.hysteresis);
        }
        if !(self.ewma_alpha > 0.0 && self.ewma_alpha <= 1.0) {
            bail!("ewma_alpha must be in (0, 1], got {}", self.ewma_alpha);
        }
        Ok(())
    }
}

/// Deterministic fault-injection schedule (ROADMAP item 5; executed by
/// `crate::fault::FaultPlan` at the `Transport` seam, so chaos runs need
/// zero engine changes).
///
/// Every decision is a pure function of `(seed, src, dst, pass
/// generation)`, so a schedule replays identically across runs — which is
/// what lets the chaos tests assert that a transiently-faulted pass,
/// retried by the engine, produces *bitwise identical* output to a
/// fault-free run. Disabled by default (all rates zero, no rank killed):
/// [`enabled`](Self::enabled) is false and `NodeFabric` builds no
/// `FaultPlan` at all, keeping the non-chaos hot path untouched.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct FaultConfig {
    /// Seed of the deterministic per-transfer hash. Knob: `fault_seed`.
    pub seed: u64,
    /// Probability in `[0, 1]` that a given (src, dst, generation)
    /// transfer fails transiently inside the window below. Knob:
    /// `fault_transient_rate`.
    pub transient_rate: f64,
    /// First pass generation (inclusive) at which transient faults may
    /// fire. Knob: `fault_transient_from`.
    pub transient_from: u64,
    /// Pass generation (exclusive) at which transient faults stop firing;
    /// `0` means open-ended. Knob: `fault_transient_until`.
    pub transient_until: u64,
    /// Rank that dies permanently (every transfer touching it fails from
    /// [`kill_epoch`](Self::kill_epoch) on). Knob: `fault_kill_rank`
    /// (`none`/`off` clears).
    pub kill_rank: Option<usize>,
    /// First pass generation (inclusive) at which [`kill_rank`]
    /// (Self::kill_rank) is dead. Knob: `fault_kill_epoch`.
    pub kill_epoch: u64,
    /// Probability in `[0, 1]` that a NIC-class transfer gets an injected
    /// delay spike. Knob: `fault_delay_rate`.
    pub delay_rate: f64,
    /// Duration of one injected NIC delay spike, microseconds. Knob:
    /// `fault_delay_us`.
    pub delay_us: u64,
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self {
            seed: 0,
            transient_rate: 0.0,
            transient_from: 1,
            transient_until: 0,
            kill_rank: None,
            kill_epoch: 1,
            delay_rate: 0.0,
            delay_us: 0,
        }
    }
}

impl FaultConfig {
    /// True when any schedule entry can ever fire (a `FaultPlan` is only
    /// constructed — and the transport only consults it — in that case).
    pub fn enabled(&self) -> bool {
        self.transient_rate > 0.0
            || self.kill_rank.is_some()
            || (self.delay_rate > 0.0 && self.delay_us > 0)
    }

    /// `ranks` is the world size the schedule will run against (a killed
    /// rank must exist).
    pub fn validate(&self, ranks: usize) -> Result<()> {
        for (name, rate) in
            [("fault_transient_rate", self.transient_rate), ("fault_delay_rate", self.delay_rate)]
        {
            if !(rate.is_finite() && (0.0..=1.0).contains(&rate)) {
                bail!("{name} must be in [0, 1], got {rate}");
            }
        }
        if self.transient_until != 0 && self.transient_until < self.transient_from {
            bail!(
                "fault_transient_until ({}) must be 0 (open-ended) or >= fault_transient_from ({})",
                self.transient_until,
                self.transient_from
            );
        }
        if let Some(r) = self.kill_rank {
            if r >= ranks {
                bail!("fault_kill_rank {r} out of range for {ranks} ranks");
            }
        }
        Ok(())
    }
}

/// Training knobs (ROADMAP item 3; consumed by `crate::train` and the
/// engine's backward path).
///
/// * `train` = `on|off` — master switch: forward passes stash their
///   routing decisions, gate probabilities and per-tile activations
///   inside the rank actors so `MoeEngine::backward` can be issued for
///   any of the last `STASH_CAP` forward epochs; `Trainer` requires it.
/// * `optimizer` = `sgd|adam` — which `train::Optimizer` example loops
///   (`examples/train_loop.rs`, `flashdmoe train`) construct.
/// * `lr` — learning rate for those loops (must be finite and positive).
/// * `weight_decay` — decoupled weight decay coefficient: the optimizer
///   shrinks every parameter by `lr · weight_decay · θ` at each step,
///   *outside* the gradient (AdamW-style, so Adam's moment estimates
///   never see the decay term). `0` (default) disables it.
/// * `lr_schedule` = `const` | `step:<every>:<gamma>` |
///   `cosine:<total>` — per-update learning-rate schedule evaluated by
///   `Trainer` ([`LrSchedule`]); `lr` is the base rate it scales.
/// * `grad_accum_steps` — micro-batches folded into one optimizer step
///   by `Trainer` (≥ 1; gradients are averaged over the window).
/// * `stash_activations` — stash forwards *without* enabling the rest of
///   the training path (e.g. to inspect backward conformance against a
///   serving config); `train=on` implies it.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct TrainConfig {
    /// Master training switch. Knob: `train=on|off`.
    pub enabled: bool,
    /// Optimizer selection for the example loops. Knob: `optimizer`.
    pub optimizer: OptimizerKind,
    /// Learning rate. Knob: `lr`.
    pub lr: f32,
    /// Decoupled weight-decay coefficient (0 disables). Knob:
    /// `weight_decay`.
    pub weight_decay: f32,
    /// Learning-rate schedule over optimizer updates. Knob:
    /// `lr_schedule`.
    pub lr_schedule: LrSchedule,
    /// Micro-batches per optimizer step. Knob: `grad_accum_steps`.
    pub grad_accum_steps: usize,
    /// Stash forward activations even with `enabled == false`. Knob:
    /// `stash_activations`.
    pub stash_activations: bool,
}

impl Default for TrainConfig {
    fn default() -> Self {
        Self {
            enabled: false,
            optimizer: OptimizerKind::Adam,
            lr: 1e-3,
            weight_decay: 0.0,
            lr_schedule: LrSchedule::Const,
            grad_accum_steps: 1,
            stash_activations: false,
        }
    }
}

impl TrainConfig {
    /// True when forward passes must retain their activation stash — the
    /// precondition for `MoeEngine::backward`.
    pub fn stash(&self) -> bool {
        self.enabled || self.stash_activations
    }

    pub fn validate(&self) -> Result<()> {
        if !(self.lr.is_finite() && self.lr > 0.0) {
            bail!("lr must be finite and positive, got {}", self.lr);
        }
        if !(self.weight_decay.is_finite() && self.weight_decay >= 0.0) {
            bail!("weight_decay must be finite and >= 0, got {}", self.weight_decay);
        }
        self.lr_schedule.validate()?;
        if self.grad_accum_steps == 0 {
            bail!("grad_accum_steps must be >= 1");
        }
        Ok(())
    }
}

/// Learning-rate schedule over *optimizer updates* (not micro-batches:
/// with `grad_accum_steps > 1` an update covers a whole accumulation
/// window). The schedule is a pure multiplier on the base `lr` —
/// [`factor`](Self::factor) maps update index → scale in `[0, 1]` — so
/// `Trainer` evaluates it right before each `Optimizer::step` and the
/// optimizer state (momentum/Adam moments) is untouched by the knob.
///
/// Knob spellings ([`parse`](Self::parse)):
///
/// * `const` — factor 1 forever (the default; bitwise-identical to the
///   pre-schedule `Trainer`).
/// * `step:<every>:<gamma>` — multiply by `gamma` after every `every`
///   updates (`factor(n) = gamma^(n / every)`).
/// * `cosine:<total>` — cosine annealing from 1 to 0 over `total`
///   updates (`factor(n) = (1 + cos(π·min(n, total)/total)) / 2`).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LrSchedule {
    /// Constant base rate (factor 1).
    Const,
    /// Multiply the rate by `gamma` after every `every` updates.
    Step {
        /// Updates between decays (≥ 1).
        every: u64,
        /// Per-decay multiplier in `(0, 1]`.
        gamma: f64,
    },
    /// Cosine annealing from the base rate to 0 across `total` updates
    /// (clamped there: `factor(n >= total) == 0`).
    Cosine {
        /// Updates the annealing spans (≥ 1).
        total: u64,
    },
}

impl LrSchedule {
    /// Scale applied to the base `lr` for optimizer update `step`
    /// (0-indexed: the first update runs at `factor(0)`, which is 1 for
    /// every variant).
    pub fn factor(&self, step: u64) -> f64 {
        match *self {
            LrSchedule::Const => 1.0,
            LrSchedule::Step { every, gamma } => gamma.powi((step / every.max(1)) as i32),
            LrSchedule::Cosine { total } => {
                let t = step.min(total) as f64 / total.max(1) as f64;
                0.5 * (1.0 + (std::f64::consts::PI * t).cos())
            }
        }
    }

    /// Canonical knob spelling (accepted by [`parse`](Self::parse)).
    pub fn name(&self) -> String {
        match *self {
            LrSchedule::Const => "const".to_string(),
            LrSchedule::Step { every, gamma } => format!("step:{every}:{gamma}"),
            LrSchedule::Cosine { total } => format!("cosine:{total}"),
        }
    }

    /// Parse a CLI/config-file value: `const`, `step:<every>:<gamma>` or
    /// `cosine:<total>`.
    pub fn parse(s: &str) -> Option<LrSchedule> {
        let s = s.to_ascii_lowercase();
        if s == "const" || s == "constant" {
            return Some(LrSchedule::Const);
        }
        if let Some(rest) = s.strip_prefix("step:") {
            let (every, gamma) = rest.split_once(':')?;
            return Some(LrSchedule::Step {
                every: every.parse().ok()?,
                gamma: gamma.parse().ok()?,
            });
        }
        if let Some(total) = s.strip_prefix("cosine:") {
            return Some(LrSchedule::Cosine { total: total.parse().ok()? });
        }
        None
    }

    pub fn validate(&self) -> Result<()> {
        match *self {
            LrSchedule::Const => {}
            LrSchedule::Step { every, gamma } => {
                if every == 0 {
                    bail!("lr_schedule step interval must be >= 1");
                }
                if !(gamma.is_finite() && gamma > 0.0 && gamma <= 1.0) {
                    bail!("lr_schedule step gamma must be in (0, 1], got {gamma}");
                }
            }
            LrSchedule::Cosine { total } => {
                if total == 0 {
                    bail!("lr_schedule cosine span must be >= 1");
                }
            }
        }
        Ok(())
    }
}

/// Which optimizer the config-driven training loops construct (the
/// `train::Optimizer` enum itself carries state; this is just the knob).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum OptimizerKind {
    Sgd,
    #[default]
    Adam,
}

impl OptimizerKind {
    pub fn name(self) -> &'static str {
        match self {
            OptimizerKind::Sgd => "sgd",
            OptimizerKind::Adam => "adam",
        }
    }

    pub fn parse(s: &str) -> Option<OptimizerKind> {
        match s.to_ascii_lowercase().as_str() {
            "sgd" => Some(OptimizerKind::Sgd),
            "adam" => Some(OptimizerKind::Adam),
            _ => None,
        }
    }
}

/// How the router treats per-expert load.
///
/// * [`Capacity`](RoutingPolicy::Capacity) — the paper's §3.2.1 contract:
///   each (source rank, expert) pair gets a fixed, bM-aligned capacity
///   buffer `roundup(max(ceil(S_r·k/E·f), bM), bM)`; over-capacity
///   (token, expert) pairs are silently dropped, so under skewed gating
///   the engine computes a *different function* than the dense reference.
/// * [`Dropless`](RoutingPolicy::Dropless) — MegaBlocks-style dropless
///   MoE: no pair is ever dropped. The symmetric heap's per-(source,
///   expert) slot region is sized to the worst case (`roundup(S_r, bM)` —
///   a source can route at most its whole batch to one expert), and
///   dispatch ships variable-length tile lists sized to the *actual*
///   routed counts, so the worst-case region costs no extra wire traffic.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum RoutingPolicy {
    /// Fixed per-(source, expert) capacity with factor `f`; overflow drops.
    Capacity(f64),
    /// Variable-capacity dispatch; every routed pair is kept.
    Dropless,
}

impl RoutingPolicy {
    /// Parse a CLI/config-file value: `dropless`, `capacity` (factor 1.0)
    /// or `capacity:<factor>` (the factor must be finite and positive).
    pub fn parse(s: &str) -> Option<RoutingPolicy> {
        match s {
            "dropless" => Some(RoutingPolicy::Dropless),
            "capacity" => Some(RoutingPolicy::Capacity(1.0)),
            _ => s
                .strip_prefix("capacity:")
                .and_then(|f| f.parse().ok())
                .filter(|f: &f64| *f > 0.0 && *f <= MAX_CAPACITY_FACTOR)
                .map(RoutingPolicy::Capacity),
        }
    }

    pub fn is_dropless(&self) -> bool {
        matches!(self, RoutingPolicy::Dropless)
    }

    /// A capacity factor must lie in `(0, MAX_CAPACITY_FACTOR]`. NaN,
    /// infinite, zero or negative factors would silently clamp every
    /// (source, expert) buffer to bM via the `ceil() as usize` saturation,
    /// and a huge finite factor (e.g. 1e300) would saturate the cast to
    /// `usize::MAX` and overflow the bM alignment — wrapping capacity to 0
    /// in release builds, i.e. silently dropping every token.
    pub fn validate(&self) -> Result<()> {
        if let RoutingPolicy::Capacity(f) = self {
            if !(*f > 0.0 && *f <= MAX_CAPACITY_FACTOR) {
                bail!(
                    "capacity factor must be in (0, {MAX_CAPACITY_FACTOR:e}], got {f}"
                );
            }
        }
        Ok(())
    }
}

/// Upper bound on a usable capacity factor: far above any practical value
/// (real deployments use f in [0.25, 8]), far below the range where the
/// `ceil() as usize` in [`ModelConfig::capacity`] could saturate/overflow.
/// The comparison `f <= MAX_CAPACITY_FACTOR` is false for NaN, so the
/// bound check also rejects non-finite factors.
pub const MAX_CAPACITY_FACTOR: f64 = 1e6;

/// Model-side configuration (mirrors `python/compile/aot.py::PRESETS`).
#[derive(Clone, Debug, PartialEq)]
pub struct ModelConfig {
    /// Token embedding dimension H.
    pub h: usize,
    /// FFN intermediate dimension D.
    pub d: usize,
    /// Total number of experts E across all ranks.
    pub e: usize,
    /// Top-k routing fan-out.
    pub k: usize,
    /// Tile height bM (the paper fixes 128).
    pub bm: usize,
    /// Tile width bN (the paper fixes 64).
    pub bn: usize,
    /// Routing policy: fixed capacity (with factor) or dropless.
    pub policy: RoutingPolicy,
}

/// System-side configuration: topology + actor resources.
#[derive(Clone, Debug, PartialEq)]
pub struct SystemConfig {
    /// Expert-parallel world size P (number of ranks).
    pub ranks: usize,
    /// Number of nodes the ranks are spread over (ranks % nodes == 0).
    pub nodes: usize,
    /// Tokens per rank S_r.
    pub s_rank: usize,
    /// Processor actors (worker threads / "SM" slots) per rank.
    pub processors: usize,
    /// Compute-backend toggle: `true` (default) runs expert GEMMs on the
    /// packed persistent-weight path (weights re-laid into NR panels once
    /// at `MoeEngine::start`, bias+activation fused into the single C
    /// write-back); `false` keeps the row-major unpacked kernels. One
    /// flag A/Bs the two on identical inputs (`cfg.set("packed", ...)`,
    /// `harness::gemm_backend_ab`, `harness::hotpath_ab`). Numerics are
    /// identical either way — the packed kernel replays the same f32
    /// accumulation order — so the toggle is purely a performance knob.
    pub packed: bool,
    /// Wire element format for dispatch/combine payloads (see
    /// [`WirePrecision`]): the symmetric heap stores, ships and *counts*
    /// bytes at this width; compute stays f32. `cfg.set("wire_precision",
    /// "bf16")` selects it; defaults to `F32` (bitwise-transparent).
    pub wire: WirePrecision,
    /// Inter-node dispatch schedule (see [`DispatchMode`]): `Flat` direct
    /// puts or `Hierarchical` coalesced per-node transfers via proxy
    /// ranks. Knobs: `topology=flat|hier` / `dispatch=...`. Irrelevant
    /// (and harmless) on single-node topologies, where every link is
    /// NVLink-class.
    pub dispatch: DispatchMode,
    /// Hot-expert replication policy (see [`ReplicationPolicy`]); the
    /// default disables replication and reserves no replica slots.
    pub replication: ReplicationPolicy,
    /// Seconds without subscriber progress before a rank declares the
    /// pass wedged and panics (watchdog; default 120). Chaos tests dial
    /// it down so wedge detection runs at test scale. Knob:
    /// `watchdog_secs`.
    pub watchdog_secs: u64,
    /// How many times the engine transparently re-fences and resubmits a
    /// failed pass before surfacing the error (0 = fail fast, the
    /// pre-retry behavior). Knob: `retry_limit`.
    pub retry_limit: usize,
    /// Deterministic fault-injection schedule (see [`FaultConfig`]);
    /// disabled by default.
    pub fault: FaultConfig,
    /// Training knobs (see [`TrainConfig`]); off by default — serving
    /// engines stash nothing and pay nothing.
    pub train: TrainConfig,
    /// How many models the engine reserves residency capacity for
    /// (`crate::registry`): every layout/flag/announce table's
    /// expert-slot dimension is multiplied by this, partitioning the
    /// symmetric heap into per-model slot bands. Default 1 — the
    /// single-model layout, byte-identical to an engine without the
    /// knob. Models beyond slot 0 are installed/evicted at epoch-fenced
    /// quiet points (`MoeEngine::register_model` / `evict_model`) and
    /// must share this config's architecture. Knob: `max_models`.
    pub max_models: usize,
}

/// Hardware cost model for the simulator, calibrated by `flashdmoe
/// calibrate` (see `sim::calibrate`). All times in seconds, bandwidth in
/// bytes/s.
#[derive(Clone, Debug, PartialEq)]
pub struct CostModel {
    /// Per-kernel-launch CPU->GPU overhead (the paper's Table 1 killer).
    pub launch_overhead: f64,
    /// Effective FLOP/s of one processor slot (per-"SM" throughput).
    pub flops_per_processor: f64,
    /// Intra-node (NVLink-class) unidirectional bandwidth.
    pub intra_bw: f64,
    /// Intra-node transfer latency per message.
    pub intra_lat: f64,
    /// Inter-node (NIC) unidirectional bandwidth.
    pub inter_bw: f64,
    /// Inter-node latency per message.
    pub inter_lat: f64,
    /// NIC receive buffer capacity (bytes) for incast modeling (Fig 17).
    /// Bounds the live transport's per-rank, per-pass receive window
    /// (`transport::InterNodeLink`): exceeding it fails the transfer and
    /// the engine reports the pass error — the measured incast overflow.
    /// Knob: `nic_buffer=<bytes>`.
    pub nic_buffer: f64,
    /// When true, the live transport injects `inter_lat + bytes /
    /// inter_bw` of real wall-clock delay per NIC transfer, so engine
    /// timings reflect the calibrated inter-node link instead of shared
    /// memory speed. Off by default (pure functional/accounting runs).
    /// Knob: `nic_delay=true|false`.
    pub nic_delay: bool,
    /// Straggler jitter: lognormal sigma applied to collective kernels.
    pub jitter_sigma: f64,
    /// Fixed host sync cost of a bulk-synchronous collective barrier.
    pub barrier_cost: f64,
    /// Bytes per scalar element in the *analytic* cost model (4 = fp32,
    /// 2 = fp16). Kept in sync with [`SystemConfig::wire`] by the
    /// `wire_precision` knob; setting `elem_bytes` directly is the
    /// deprecated back-channel (see [`Config::set`]).
    pub elem_bytes: f64,
}

impl CostModel {
    /// H100-NVLink-flavoured defaults (single node). Absolute values are
    /// placeholders until `calibrate` replaces `flops_per_processor`; the
    /// *ratios* (launch overhead vs transfer vs flops) drive the figures.
    pub fn h100_nvlink() -> Self {
        Self {
            // framework-level kernel-launch gap (CUDA launch + framework
            // dispatcher + inter-op CPU stall, as seen in the paper's
            // Fig 5 CUDA-API traces; the flash engine pays it exactly once)
            launch_overhead: 100e-6,
            // ~0.4 TFLOP/s fp32 per SM-analog (H100: 132 SMs, ~53 TFLOP/s
            // aggregate fp32 without sparsity); replaced by `calibrate` for
            // measured-mode comparisons.
            flops_per_processor: 4.0e11,
            intra_bw: 300e9,
            intra_lat: 2e-6,
            inter_bw: 25e9,
            inter_lat: 5e-6,
            nic_buffer: 64.0 * 1024.0 * 1024.0,
            nic_delay: false,
            jitter_sigma: 0.05,
            barrier_cost: 10e-6,
            elem_bytes: 4.0,
        }
    }

    /// Commercial-VM flavour: much heavier jitter (paper Table 2: p95 11.4x).
    pub fn commercial_vm() -> Self {
        Self { jitter_sigma: 0.9, barrier_cost: 30e-6, ..Self::h100_nvlink() }
    }

    /// Supercomputer flavour: tightly tuned against software jitter.
    pub fn supercomputer() -> Self {
        Self { jitter_sigma: 0.025, ..Self::h100_nvlink() }
    }

    pub fn with_fp16(mut self) -> Self {
        self.elem_bytes = 2.0;
        self
    }
}

/// The complete experiment configuration.
#[derive(Clone, Debug, PartialEq)]
pub struct Config {
    pub model: ModelConfig,
    pub system: SystemConfig,
    pub cost: CostModel,
}

impl ModelConfig {
    /// Capacity factor `f` of the [`RoutingPolicy::Capacity`] policy
    /// (1.0 under `Dropless`, where it only feeds Table-3-style reports).
    pub fn capacity_factor(&self) -> f64 {
        match self.policy {
            RoutingPolicy::Capacity(f) => f,
            RoutingPolicy::Dropless => 1.0,
        }
    }

    /// Aligned per-(source rank, expert) capacity (paper §3.2.1):
    /// `roundup(max(ceil(S_r·k/E·f), bM), bM)`. This is the *Capacity
    /// policy's* buffer size; policy-aware sizing is [`slot_capacity`]
    /// (the two agree under `Capacity`).
    ///
    /// [`slot_capacity`]: ModelConfig::slot_capacity
    pub fn capacity(&self, s_rank: usize) -> usize {
        let f = self.capacity_factor();
        let raw = (s_rank as f64 * self.k as f64 / self.e as f64 * f).ceil() as usize;
        let cap = raw.max(self.bm);
        cap.div_ceil(self.bm) * self.bm
    }

    /// Policy-aware per-(source rank, expert) slot-region size (bM-aligned).
    /// Under `Capacity` this is [`capacity`](ModelConfig::capacity); under
    /// `Dropless` it is the worst case `roundup(max(S_r, bM), bM)` — a
    /// source routes each token to an expert at most once, so one expert
    /// can receive at most the source's whole batch. Dispatch only ever
    /// ships the tiles that actually hold rows, so the worst-case region
    /// costs memory, never wire traffic.
    pub fn slot_capacity(&self, s_rank: usize) -> usize {
        match self.policy {
            RoutingPolicy::Capacity(_) => self.capacity(s_rank),
            RoutingPolicy::Dropless => s_rank.max(self.bm).div_ceil(self.bm) * self.bm,
        }
    }

    /// Tile slots per (rank, expert) region under the configured policy.
    pub fn tiles_per_capacity(&self, s_rank: usize) -> usize {
        self.slot_capacity(s_rank) / self.bm
    }

    /// FLOPs of one expert-FFN application to `rows` tokens (2 GEMMs).
    pub fn ffn_flops(&self, rows: usize) -> f64 {
        2.0 * rows as f64 * self.h as f64 * self.d as f64 * 2.0
    }

    /// FLOPs of the gate logit GEMM for `rows` tokens.
    pub fn gate_flops(&self, rows: usize) -> f64 {
        2.0 * rows as f64 * self.h as f64 * self.e as f64
    }

    /// Bytes of one (bM, H) token tile at `elem_bytes` per scalar.
    pub fn tile_bytes(&self, elem_bytes: f64) -> f64 {
        self.bm as f64 * self.h as f64 * elem_bytes
    }

    /// VMEM footprint estimate (bytes) for the fused FFN tile kernel: the
    /// (bM, H) input, both weight matrices, the (bM, D) intermediate and
    /// the (bM, H) output resident. This is the L1 perf-profile number
    /// recorded in DESIGN.md §9.
    pub fn ffn_tile_vmem_bytes(&self) -> usize {
        4 * (self.bm * self.h * 2 + self.h * self.d + self.d * self.h + self.bm * self.d)
    }
}

impl SystemConfig {
    /// Total tokens across ranks.
    pub fn s_total(&self) -> usize {
        self.ranks * self.s_rank
    }

    /// Row capacity of one engine pass — the hard ceiling on a serving
    /// batch and the denominator of `PassMetrics::batch_fill`. A
    /// variable-shape pass may submit any `0..=s_rank` rows per rank, so
    /// this is the most any single pass can carry: exactly
    /// [`s_total`](Self::s_total), under its serving-side name.
    pub fn max_batch_tokens(&self) -> usize {
        self.s_total()
    }

    /// Ranks per node.
    pub fn ranks_per_node(&self) -> usize {
        self.ranks / self.nodes
    }

    /// True if two ranks share a node.
    pub fn same_node(&self, a: usize, b: usize) -> bool {
        a / self.ranks_per_node() == b / self.ranks_per_node()
    }

    pub fn validate(&self) -> Result<()> {
        if self.ranks == 0 || self.nodes == 0 {
            bail!("ranks/nodes must be positive");
        }
        if self.ranks % self.nodes != 0 {
            bail!("ranks ({}) must divide evenly over nodes ({})", self.ranks, self.nodes);
        }
        if self.processors == 0 {
            bail!("need at least one processor actor per rank");
        }
        if self.max_models == 0 {
            bail!("max_models must be >= 1 (slot 0 hosts the anchor model)");
        }
        Ok(())
    }
}

impl Config {
    /// Named presets. `tiny`/`default`/`perf` match the AOT manifest; the
    /// `paper_*` presets mirror the paper's evaluation testbeds (sim-only).
    pub fn preset(name: &str) -> Result<Config> {
        let cfg = match name {
            "tiny" => Config {
                model: ModelConfig {
                    h: 64,
                    d: 128,
                    e: 8,
                    k: 2,
                    bm: 32,
                    bn: 32,
                    policy: RoutingPolicy::Capacity(1.0),
                },
                system: SystemConfig {
                    ranks: 2,
                    nodes: 1,
                    s_rank: 128,
                    processors: 4,
                    packed: true,
                    wire: WirePrecision::F32,
                    dispatch: DispatchMode::Flat,
                    replication: ReplicationPolicy::default(),
                    watchdog_secs: 120,
                    retry_limit: 0,
                    fault: FaultConfig::default(),
                    train: TrainConfig::default(),
                    max_models: 1,
                },
                cost: CostModel::h100_nvlink(),
            },
            "default" => Config {
                model: ModelConfig {
                    h: 256,
                    d: 512,
                    e: 16,
                    k: 2,
                    bm: 128,
                    bn: 64,
                    policy: RoutingPolicy::Capacity(1.0),
                },
                system: SystemConfig {
                    ranks: 4,
                    nodes: 1,
                    s_rank: 512,
                    processors: 4,
                    packed: true,
                    wire: WirePrecision::F32,
                    dispatch: DispatchMode::Flat,
                    replication: ReplicationPolicy::default(),
                    watchdog_secs: 120,
                    retry_limit: 0,
                    fault: FaultConfig::default(),
                    train: TrainConfig::default(),
                    max_models: 1,
                },
                cost: CostModel::h100_nvlink(),
            },
            "perf" => Config {
                model: ModelConfig {
                    h: 512,
                    d: 1024,
                    e: 16,
                    k: 2,
                    bm: 128,
                    bn: 64,
                    policy: RoutingPolicy::Capacity(1.0),
                },
                system: SystemConfig {
                    ranks: 4,
                    nodes: 1,
                    s_rank: 1024,
                    processors: 4,
                    packed: true,
                    wire: WirePrecision::F32,
                    dispatch: DispatchMode::Flat,
                    replication: ReplicationPolicy::default(),
                    watchdog_secs: 120,
                    retry_limit: 0,
                    fault: FaultConfig::default(),
                    train: TrainConfig::default(),
                    max_models: 1,
                },
                cost: CostModel::h100_nvlink(),
            },
            // Paper §4: 8xH100, E up to 128, T up to 16K, H=2048, D=2048.
            "paper_h100x8" => Config {
                model: ModelConfig {
                    h: 2048,
                    d: 2048,
                    e: 64,
                    k: 2,
                    bm: 128,
                    bn: 64,
                    policy: RoutingPolicy::Capacity(1.0),
                },
                system: SystemConfig {
                    ranks: 8,
                    nodes: 1,
                    s_rank: 8192,
                    processors: 132,
                    packed: true,
                    wire: WirePrecision::F32,
                    dispatch: DispatchMode::Flat,
                    replication: ReplicationPolicy::default(),
                    watchdog_secs: 120,
                    retry_limit: 0,
                    fault: FaultConfig::default(),
                    train: TrainConfig::default(),
                    max_models: 1,
                },
                cost: CostModel::h100_nvlink(),
            },
            // Paper Fig 5/11: 2xA100 NVLink, E=64, T=8K.
            "paper_a100x2" => Config {
                model: ModelConfig {
                    h: 2048,
                    d: 2048,
                    e: 64,
                    k: 2,
                    bm: 128,
                    bn: 64,
                    policy: RoutingPolicy::Capacity(1.0),
                },
                system: SystemConfig {
                    ranks: 2,
                    nodes: 1,
                    s_rank: 8192,
                    processors: 108,
                    packed: true,
                    wire: WirePrecision::F32,
                    dispatch: DispatchMode::Flat,
                    replication: ReplicationPolicy::default(),
                    watchdog_secs: 120,
                    retry_limit: 0,
                    fault: FaultConfig::default(),
                    train: TrainConfig::default(),
                    max_models: 1,
                },
                cost: CostModel::h100_nvlink(),
            },
            // Paper §F: 4 nodes x 4 A100, 1 local expert, 25 GB/s NIC.
            // nic_buffer is sized so the observed incast failure appears
            // past 2048 tokens/GPU (Fig 17's non-termination), and the
            // hierarchical (coalesced, FSMoE-style) dispatch schedule is
            // on — the flat baseline is one `topology=flat` override away.
            "paper_multinode" => Config {
                model: ModelConfig {
                    h: 1024,
                    d: 4096,
                    e: 16,
                    k: 2,
                    bm: 128,
                    bn: 64,
                    policy: RoutingPolicy::Capacity(1.0),
                },
                system: SystemConfig {
                    ranks: 16,
                    nodes: 4,
                    s_rank: 1024,
                    processors: 108,
                    packed: true,
                    wire: WirePrecision::F32,
                    dispatch: DispatchMode::Hierarchical,
                    replication: ReplicationPolicy::default(),
                    watchdog_secs: 120,
                    retry_limit: 0,
                    fault: FaultConfig::default(),
                    train: TrainConfig::default(),
                    max_models: 1,
                },
                cost: CostModel { nic_buffer: 32.0 * 1024.0 * 1024.0, ..CostModel::h100_nvlink() },
            },
            other => bail!("unknown preset '{other}' (try tiny/default/perf/paper_h100x8/paper_a100x2/paper_multinode)"),
        };
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        self.system.validate()?;
        self.system.replication.validate()?;
        self.system.fault.validate(self.system.ranks)?;
        self.system.train.validate()?;
        if self.system.watchdog_secs == 0 {
            bail!("watchdog_secs must be >= 1 (the watchdog cannot be disabled)");
        }
        let m = &self.model;
        m.policy.validate()?;
        if m.e % self.system.ranks != 0 {
            bail!("experts ({}) must divide evenly over ranks ({})", m.e, self.system.ranks);
        }
        if self.system.s_rank % m.bm != 0 {
            bail!("s_rank ({}) must be a multiple of bM ({})", self.system.s_rank, m.bm);
        }
        if m.d % m.bn != 0 || m.h % m.bn != 0 {
            bail!("D ({}) and H ({}) must be multiples of bN ({})", m.d, m.h, m.bn);
        }
        if m.k == 0 || m.k > m.e {
            bail!("k ({}) must be in 1..=E ({})", m.k, m.e);
        }
        Ok(())
    }

    /// Local experts per rank.
    pub fn local_experts(&self) -> usize {
        self.model.e / self.system.ranks
    }

    /// Owning rank of global expert `e` — the *primary* location. Under
    /// an enabled [`ReplicationPolicy`] a hot expert may additionally be
    /// served from replica slots on other ranks; the dynamic map is
    /// `crate::placement::Placement` (whose `owner_of` agrees with this).
    pub fn owner_of(&self, e: usize) -> usize {
        e / self.local_experts()
    }

    /// Spare replica expert slots per rank: `replicate_top` when the
    /// replication policy is enabled, else 0. Every layout/flag/announce
    /// table sizes its expert dimension as `local_experts() +
    /// replica_slots()`.
    pub fn replica_slots(&self) -> usize {
        if self.system.replication.enabled() {
            self.system.replication.top_r
        } else {
            0
        }
    }

    /// Apply a `key=value` override (used by the CLI and config files).
    pub fn set(&mut self, key: &str, value: &str) -> Result<()> {
        let u = || value.parse::<usize>().with_context(|| format!("{key}={value}: not an integer"));
        let f = || value.parse::<f64>().with_context(|| format!("{key}={value}: not a number"));
        match key {
            "h" => self.model.h = u()?,
            "d" => self.model.d = u()?,
            "e" | "experts" => self.model.e = u()?,
            "k" | "topk" => self.model.k = u()?,
            "bm" => self.model.bm = u()?,
            "bn" => self.model.bn = u()?,
            "capacity_factor" => self.model.policy = RoutingPolicy::Capacity(f()?),
            "routing_policy" | "policy" => match RoutingPolicy::parse(value) {
                Some(p) => self.model.policy = p,
                None => bail!(
                    "{key}={value}: expected 'dropless', 'capacity' or 'capacity:<factor>'"
                ),
            },
            "ranks" => self.system.ranks = u()?,
            "nodes" => self.system.nodes = u()?,
            "s_rank" | "tokens" => self.system.s_rank = u()?,
            "processors" => self.system.processors = u()?,
            "packed" => {
                self.system.packed = match value {
                    "1" | "true" | "on" => true,
                    "0" | "false" | "off" => false,
                    other => bail!("packed={other}: expected true/false/1/0/on/off"),
                }
            }
            // The wire-format knob: also syncs the simulator's per-element
            // byte cost so modeled and measured traffic agree.
            "wire_precision" | "wire" => match WirePrecision::parse(value) {
                Some(w) => {
                    self.system.wire = w;
                    self.cost.elem_bytes = w.bytes() as f64;
                }
                None => bail!("{key}={value}: expected 'f32', 'f16' or 'bf16'"),
            },
            // The inter-node dispatch schedule (see the transport module).
            "topology" | "dispatch" => match DispatchMode::parse(value) {
                Some(m) => self.system.dispatch = m,
                None => bail!("{key}={value}: expected 'flat' or 'hier'/'hierarchical'"),
            },
            // Hot-expert replication knobs (see ReplicationPolicy).
            "replicate_top" | "top_r" => self.system.replication.top_r = u()?,
            "replicas" => self.system.replication.replicas = u()?,
            "replication_hysteresis" | "hysteresis" => {
                self.system.replication.hysteresis = f()?
            }
            "ewma_alpha" => self.system.replication.ewma_alpha = f()?,
            // Training knobs (see TrainConfig and `crate::train`).
            "train" => {
                self.system.train.enabled = match value {
                    "1" | "true" | "on" => true,
                    "0" | "false" | "off" => false,
                    other => bail!("train={other}: expected true/false/1/0/on/off"),
                }
            }
            "optimizer" => match OptimizerKind::parse(value) {
                Some(o) => self.system.train.optimizer = o,
                None => bail!("{key}={value}: expected 'sgd' or 'adam'"),
            },
            "lr" | "learning_rate" => {
                self.system.train.lr =
                    value.parse().with_context(|| format!("{key}={value}: not a number"))?
            }
            "weight_decay" => {
                self.system.train.weight_decay =
                    value.parse().with_context(|| format!("{key}={value}: not a number"))?
            }
            "lr_schedule" => match LrSchedule::parse(value) {
                Some(s) => self.system.train.lr_schedule = s,
                None => bail!(
                    "{key}={value}: expected 'const', 'step:<every>:<gamma>' or 'cosine:<total>'"
                ),
            },
            "grad_accum_steps" => self.system.train.grad_accum_steps = u()?,
            "stash_activations" => {
                self.system.train.stash_activations = match value {
                    "1" | "true" | "on" => true,
                    "0" | "false" | "off" => false,
                    other => bail!("stash_activations={other}: expected true/false/1/0/on/off"),
                }
            }
            // Fault-tolerance knobs (see FaultConfig and `crate::fault`).
            "watchdog_secs" => {
                self.system.watchdog_secs =
                    value.parse().with_context(|| format!("{key}={value}: not an integer"))?
            }
            "retry_limit" => self.system.retry_limit = u()?,
            // Multi-model residency capacity (see `crate::registry`).
            "max_models" => self.system.max_models = u()?,
            "fault_seed" => {
                self.system.fault.seed =
                    value.parse().with_context(|| format!("{key}={value}: not an integer"))?
            }
            "fault_transient_rate" => self.system.fault.transient_rate = f()?,
            "fault_transient_from" => {
                self.system.fault.transient_from =
                    value.parse().with_context(|| format!("{key}={value}: not an integer"))?
            }
            "fault_transient_until" => {
                self.system.fault.transient_until =
                    value.parse().with_context(|| format!("{key}={value}: not an integer"))?
            }
            "fault_kill_rank" | "kill_rank" => {
                self.system.fault.kill_rank = match value {
                    "none" | "off" => None,
                    _ => Some(u()?),
                }
            }
            "fault_kill_epoch" | "kill_epoch" => {
                self.system.fault.kill_epoch =
                    value.parse().with_context(|| format!("{key}={value}: not an integer"))?
            }
            "fault_delay_rate" => self.system.fault.delay_rate = f()?,
            "fault_delay_us" => {
                self.system.fault.delay_us =
                    value.parse().with_context(|| format!("{key}={value}: not an integer"))?
            }
            "launch_overhead" => self.cost.launch_overhead = f()?,
            "flops_per_processor" => self.cost.flops_per_processor = f()?,
            "intra_bw" => self.cost.intra_bw = f()?,
            "inter_bw" | "nic_bandwidth" => self.cost.inter_bw = f()?,
            "inter_lat" | "nic_latency" => self.cost.inter_lat = f()?,
            "nic_buffer" => self.cost.nic_buffer = f()?,
            "nic_delay" => {
                self.cost.nic_delay = match value {
                    "1" | "true" | "on" => true,
                    "0" | "false" | "off" => false,
                    other => bail!("nic_delay={other}: expected true/false/1/0/on/off"),
                }
            }
            "jitter_sigma" => self.cost.jitter_sigma = f()?,
            "barrier_cost" => self.cost.barrier_cost = f()?,
            // DEPRECATED back-channel, kept as a shim: `elem_bytes` used to
            // be the only way to express a narrower dtype, and only the
            // analytic cost model ever saw it. It now drives the real wire
            // format too — but only when the requested *width* actually
            // differs from the configured wire's, so `elem_bytes=2` after
            // `wire_precision=bf16` (already 2 bytes/elem) is the no-op it
            // looks like rather than a silent bf16→f16 downgrade. Widths
            // other than 2/4 are simulator-only what-ifs (the cost model
            // keeps them; the real wire stays as configured). Prefer
            // `wire_precision`.
            "elem_bytes" => {
                let v = f()?;
                self.cost.elem_bytes = v;
                if v == 4.0 && self.system.wire.bytes() != 4 {
                    self.system.wire = WirePrecision::F32;
                } else if v == 2.0 && self.system.wire.bytes() != 2 {
                    self.system.wire = WirePrecision::F16;
                }
            }
            other => bail!("unknown config key '{other}'"),
        }
        Ok(())
    }

    /// Load `KEY=VALUE` lines ('#' comments allowed) over a preset base.
    pub fn from_file(path: &str) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let mut preset = "default".to_string();
        for line in text.lines() {
            let line = line.split('#').next().unwrap_or("").trim();
            if let Some(v) = line.strip_prefix("preset=") {
                preset = v.trim().to_string();
            }
        }
        let mut cfg = Config::preset(&preset)?;
        for (ln, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() || line.starts_with("preset=") {
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("{path}:{}: expected KEY=VALUE", ln + 1))?;
            cfg.set(k.trim(), v.trim())?;
        }
        cfg.validate()?;
        Ok(cfg)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_validate() {
        for p in ["tiny", "default", "perf", "paper_h100x8", "paper_a100x2", "paper_multinode"] {
            Config::preset(p).unwrap();
        }
        assert!(Config::preset("nope").is_err());
    }

    #[test]
    fn capacity_matches_python_math() {
        // mirrors python expert_capacity(512, 16, 2, 1.0, 128) == 128
        let cfg = Config::preset("default").unwrap();
        assert_eq!(cfg.model.capacity(512), 128);
        // tiny: ceil(128*2/8) = 32 -> max(32,32)=32
        let tiny = Config::preset("tiny").unwrap();
        assert_eq!(tiny.model.capacity(128), 32);
    }

    #[test]
    fn capacity_is_aligned_and_at_least_bm() {
        let m = ModelConfig {
            h: 8,
            d: 8,
            e: 64,
            k: 2,
            bm: 128,
            bn: 8,
            policy: RoutingPolicy::Capacity(1.0),
        };
        // tiny load: raw capacity would be 1, must clamp to bM
        assert_eq!(m.capacity(16), 128);
        // big load: stays aligned
        let c = m.capacity(16384);
        assert_eq!(c % 128, 0);
        assert!(c >= 16384 * 2 / 64);
    }

    #[test]
    fn table3_capacity_rows() {
        // Paper Table 3 `max(bM, EC)` column (T tokens spread over 8 GPUs
        // is not how they count — EC is per total tokens/E there; verify the
        // alignment rule reproduces the max(bM, EC) column for T=4K..16K).
        let mk = |e| ModelConfig {
            h: 2048,
            d: 2048,
            e,
            k: 1,
            bm: 128,
            bn: 64,
            policy: RoutingPolicy::Capacity(1.0),
        };
        assert_eq!(mk(16).capacity(4096), 256);
        assert_eq!(mk(32).capacity(4096), 128);
        assert_eq!(mk(64).capacity(4096), 128); // EC=64 -> clamp to bM
        assert_eq!(mk(16).capacity(16384), 1024);
    }

    #[test]
    fn dropless_slot_capacity_covers_worst_case() {
        let mut m =
            ModelConfig { h: 8, d: 8, e: 8, k: 2, bm: 32, bn: 8, policy: RoutingPolicy::Dropless };
        // one source can route at most its whole batch to a single expert
        assert_eq!(m.slot_capacity(128), 128);
        assert_eq!(m.slot_capacity(130), 160, "rounded up to bM");
        assert_eq!(m.slot_capacity(16), 32, "at least one tile");
        assert_eq!(m.tiles_per_capacity(128), 4);
        // under Capacity the two sizings agree
        m.policy = RoutingPolicy::Capacity(1.0);
        assert_eq!(m.slot_capacity(128), m.capacity(128));
    }

    #[test]
    fn routing_policy_overrides() {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.set("routing_policy", "dropless").unwrap();
        assert_eq!(cfg.model.policy, RoutingPolicy::Dropless);
        assert!(cfg.model.policy.is_dropless());
        cfg.validate().unwrap();
        cfg.set("capacity_factor", "1.5").unwrap();
        assert_eq!(cfg.model.policy, RoutingPolicy::Capacity(1.5));
        assert_eq!(cfg.model.capacity_factor(), 1.5);
        cfg.set("routing_policy", "capacity:0.5").unwrap();
        assert_eq!(cfg.model.policy, RoutingPolicy::Capacity(0.5));
        cfg.set("policy", "capacity").unwrap();
        assert_eq!(cfg.model.policy, RoutingPolicy::Capacity(1.0));
        assert!(cfg.set("routing_policy", "nope").is_err());
    }

    #[test]
    fn train_knobs_roundtrip_and_validate() {
        let mut cfg = Config::preset("tiny").unwrap();
        assert!(!cfg.system.train.enabled, "training is off by default");
        assert!(!cfg.system.train.stash(), "no stash without train/stash_activations");
        cfg.set("train", "on").unwrap();
        cfg.set("optimizer", "sgd").unwrap();
        cfg.set("lr", "0.05").unwrap();
        cfg.set("grad_accum_steps", "4").unwrap();
        cfg.validate().unwrap();
        assert!(cfg.system.train.enabled && cfg.system.train.stash());
        assert_eq!(cfg.system.train.optimizer, OptimizerKind::Sgd);
        assert_eq!(cfg.system.train.optimizer.name(), "sgd");
        assert_eq!(cfg.system.train.lr, 0.05);
        assert_eq!(cfg.system.train.grad_accum_steps, 4);
        // stash_activations turns on the stash without the training switch
        cfg.set("train", "off").unwrap();
        cfg.set("stash_activations", "on").unwrap();
        assert!(!cfg.system.train.enabled && cfg.system.train.stash());
        // degenerate values are rejected by validate()
        cfg.set("lr", "0").unwrap();
        assert!(cfg.validate().is_err(), "lr=0 must fail");
        cfg.set("lr", "nan").unwrap();
        assert!(cfg.validate().is_err(), "lr=nan must fail");
        cfg.set("lr", "1e-3").unwrap();
        cfg.set("grad_accum_steps", "0").unwrap();
        assert!(cfg.validate().is_err(), "grad_accum_steps=0 must fail");
        cfg.set("grad_accum_steps", "1").unwrap();
        cfg.validate().unwrap();
        assert!(cfg.set("optimizer", "lion").is_err());
        assert!(cfg.set("train", "maybe").is_err());
    }

    #[test]
    fn max_models_knob_parses_and_defaults_to_one() {
        let mut cfg = Config::preset("tiny").unwrap();
        assert_eq!(cfg.system.max_models, 1, "single-model residency is the default");
        cfg.set("max_models", "3").unwrap();
        assert_eq!(cfg.system.max_models, 3);
        cfg.validate().unwrap();
        cfg.set("max_models", "0").unwrap();
        assert!(cfg.validate().is_err(), "max_models=0 must fail");
        assert!(cfg.set("max_models", "two").is_err());
    }

    #[test]
    fn lr_schedule_and_weight_decay_knobs() {
        let mut cfg = Config::preset("tiny").unwrap();
        assert_eq!(cfg.system.train.lr_schedule, LrSchedule::Const);
        assert_eq!(cfg.system.train.weight_decay, 0.0);
        cfg.set("weight_decay", "0.01").unwrap();
        assert_eq!(cfg.system.train.weight_decay, 0.01);
        cfg.validate().unwrap();
        cfg.set("lr_schedule", "step:10:0.5").unwrap();
        assert_eq!(cfg.system.train.lr_schedule, LrSchedule::Step { every: 10, gamma: 0.5 });
        cfg.validate().unwrap();
        cfg.set("lr_schedule", "cosine:100").unwrap();
        assert_eq!(cfg.system.train.lr_schedule, LrSchedule::Cosine { total: 100 });
        cfg.set("lr_schedule", "const").unwrap();
        assert_eq!(cfg.system.train.lr_schedule, LrSchedule::Const);
        assert!(cfg.set("lr_schedule", "linear:10").is_err());
        assert!(cfg.set("lr_schedule", "step:10").is_err(), "step needs a gamma");
        // degenerate values are rejected by validate()
        for (k, v) in [
            ("weight_decay", "-0.1"),
            ("weight_decay", "nan"),
            ("lr_schedule", "step:0:0.5"),
            ("lr_schedule", "step:5:1.5"),
            ("lr_schedule", "cosine:0"),
        ] {
            let mut bad = cfg.clone();
            bad.set(k, v).unwrap();
            assert!(bad.validate().is_err(), "{k}={v} must fail validation");
        }
    }

    #[test]
    fn lr_schedule_factors() {
        assert_eq!(LrSchedule::Const.factor(123), 1.0);
        let s = LrSchedule::Step { every: 10, gamma: 0.5 };
        assert_eq!(s.factor(0), 1.0);
        assert_eq!(s.factor(9), 1.0);
        assert_eq!(s.factor(10), 0.5);
        assert_eq!(s.factor(25), 0.25);
        let c = LrSchedule::Cosine { total: 100 };
        assert_eq!(c.factor(0), 1.0);
        assert!((c.factor(50) - 0.5).abs() < 1e-12);
        assert!(c.factor(100) < 1e-12, "annealed to ~0 at the end");
        assert!(c.factor(1000) < 1e-12, "clamped past total");
        assert!(c.factor(25) > c.factor(75), "monotone decreasing");
        // name() roundtrips through parse()
        for s in [LrSchedule::Const, s, c] {
            assert_eq!(LrSchedule::parse(&s.name()), Some(s));
        }
    }

    #[test]
    fn degenerate_capacity_factors_are_rejected() {
        // parse refuses non-finite, non-positive and absurdly large factors
        let bad = ["capacity:nan", "capacity:inf", "capacity:-1", "capacity:0", "capacity:1e300"];
        for b in bad {
            assert!(RoutingPolicy::parse(b).is_none(), "{b} must not parse");
        }
        // and validate() catches a factor smuggled in via capacity_factor
        let mut cfg = Config::preset("tiny").unwrap();
        for b in ["-1", "nan", "1e300"] {
            cfg.set("capacity_factor", b).unwrap();
            assert!(cfg.validate().is_err(), "factor {b} must fail validation");
        }
        cfg.set("capacity_factor", "0.5").unwrap();
        cfg.validate().unwrap();
    }

    #[test]
    fn packed_toggle_parses_and_defaults_on() {
        let mut cfg = Config::preset("tiny").unwrap();
        assert!(cfg.system.packed, "packed hot path is the default");
        cfg.set("packed", "false").unwrap();
        assert!(!cfg.system.packed);
        cfg.set("packed", "1").unwrap();
        assert!(cfg.system.packed);
        cfg.set("packed", "off").unwrap();
        assert!(!cfg.system.packed);
        assert!(cfg.set("packed", "maybe").is_err());
        cfg.validate().unwrap();
    }

    #[test]
    fn wire_precision_knob_parses_and_defaults_to_f32() {
        let mut cfg = Config::preset("tiny").unwrap();
        assert_eq!(cfg.system.wire, WirePrecision::F32, "f32 wire is the default");
        assert!(!cfg.system.wire.is_reduced());
        for (v, want, bytes) in [
            ("bf16", WirePrecision::Bf16, 2),
            ("f16", WirePrecision::F16, 2),
            ("fp16", WirePrecision::F16, 2),
            ("F32", WirePrecision::F32, 4),
            ("bfloat16", WirePrecision::Bf16, 2),
        ] {
            cfg.set("wire_precision", v).unwrap();
            assert_eq!(cfg.system.wire, want, "wire_precision={v}");
            assert_eq!(cfg.system.wire.bytes(), bytes);
            // the analytic cost model follows the real wire width
            assert_eq!(cfg.cost.elem_bytes, bytes as f64);
            cfg.validate().unwrap();
        }
        assert!(cfg.set("wire_precision", "int8").is_err());
        assert!(cfg.set("wire", "f16").is_ok(), "short spelling accepted");
        assert_eq!(cfg.system.wire, WirePrecision::F16);
    }

    #[test]
    fn elem_bytes_shim_still_drives_the_wire_format() {
        // the deprecated float knob maps onto the typed one
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.set("elem_bytes", "2").unwrap();
        assert_eq!(cfg.system.wire, WirePrecision::F16);
        assert_eq!(cfg.cost.elem_bytes, 2.0);
        cfg.set("elem_bytes", "4").unwrap();
        assert_eq!(cfg.system.wire, WirePrecision::F32);
        // a width-consistent elem_bytes never downgrades an explicit
        // format choice: bf16 is already 2 bytes/elem, so elem_bytes=2
        // is the no-op it looks like (not a silent bf16 -> f16 flip)
        cfg.set("wire_precision", "bf16").unwrap();
        cfg.set("elem_bytes", "2").unwrap();
        assert_eq!(cfg.system.wire, WirePrecision::Bf16, "no bf16->f16 downgrade");
        // ...while a *different* width still converts (bf16 -> f32)
        cfg.set("elem_bytes", "4").unwrap();
        assert_eq!(cfg.system.wire, WirePrecision::F32);
        // exotic widths remain cost-model-only what-ifs
        cfg.set("wire_precision", "bf16").unwrap();
        cfg.set("elem_bytes", "1.5").unwrap();
        assert_eq!(cfg.cost.elem_bytes, 1.5);
        assert_eq!(cfg.system.wire, WirePrecision::Bf16, "real wire unchanged");
    }

    #[test]
    fn wire_precision_tolerances_are_ordered() {
        // wider mantissa => tighter documented conformance bound
        assert!(WirePrecision::F32.conformance_tol() < WirePrecision::F16.conformance_tol());
        assert!(WirePrecision::F16.conformance_tol() < WirePrecision::Bf16.conformance_tol());
        for p in [WirePrecision::F32, WirePrecision::F16, WirePrecision::Bf16] {
            assert_eq!(WirePrecision::parse(p.name()), Some(p), "name roundtrips");
        }
    }

    #[test]
    fn owner_and_locality() {
        let cfg = Config::preset("default").unwrap(); // 16 experts / 4 ranks
        assert_eq!(cfg.local_experts(), 4);
        assert_eq!(cfg.owner_of(0), 0);
        assert_eq!(cfg.owner_of(5), 1);
        assert_eq!(cfg.owner_of(15), 3);
    }

    #[test]
    fn overrides_and_validation() {
        let mut cfg = Config::preset("default").unwrap();
        cfg.set("tokens", "1024").unwrap();
        assert_eq!(cfg.system.s_rank, 1024);
        cfg.set("e", "17").unwrap();
        assert!(cfg.validate().is_err(), "17 experts over 4 ranks must fail");
        assert!(cfg.set("bogus", "1").is_err());
    }

    #[test]
    fn max_batch_tokens_is_one_full_pass() {
        let cfg = Config::preset("tiny").unwrap(); // 2 ranks x 128 tokens
        assert_eq!(cfg.system.max_batch_tokens(), 256);
        assert_eq!(cfg.system.max_batch_tokens(), cfg.system.s_total());
    }

    #[test]
    fn multinode_topology() {
        let cfg = Config::preset("paper_multinode").unwrap();
        assert_eq!(cfg.system.ranks_per_node(), 4);
        assert!(cfg.system.same_node(0, 3));
        assert!(!cfg.system.same_node(3, 4));
        // the multi-node preset ships the coalesced two-level schedule
        assert!(cfg.system.dispatch.is_hierarchical());
    }

    #[test]
    fn dispatch_mode_and_nic_knobs() {
        let mut cfg = Config::preset("tiny").unwrap();
        assert_eq!(cfg.system.dispatch, DispatchMode::Flat, "flat is the default");
        cfg.set("topology", "hier").unwrap();
        assert!(cfg.system.dispatch.is_hierarchical());
        cfg.set("dispatch", "flat").unwrap();
        assert_eq!(cfg.system.dispatch, DispatchMode::Flat);
        cfg.set("dispatch", "hierarchical").unwrap();
        assert_eq!(cfg.system.dispatch, DispatchMode::Hierarchical);
        assert!(cfg.set("topology", "mesh").is_err());
        for m in [DispatchMode::Flat, DispatchMode::Hierarchical] {
            assert_eq!(DispatchMode::parse(m.name()), Some(m), "name roundtrips");
        }
        // NIC spellings hit the same cost-model fields as inter_*
        cfg.set("nic_bandwidth", "12.5e9").unwrap();
        assert_eq!(cfg.cost.inter_bw, 12.5e9);
        cfg.set("nic_latency", "7e-6").unwrap();
        assert_eq!(cfg.cost.inter_lat, 7e-6);
        cfg.set("nic_buffer", "1048576").unwrap();
        assert_eq!(cfg.cost.nic_buffer, 1048576.0);
        assert!(!cfg.cost.nic_delay, "delay injection is opt-in");
        cfg.set("nic_delay", "true").unwrap();
        assert!(cfg.cost.nic_delay);
        cfg.set("nic_delay", "off").unwrap();
        assert!(!cfg.cost.nic_delay);
        assert!(cfg.set("nic_delay", "maybe").is_err());
        cfg.validate().unwrap();
    }

    #[test]
    fn replication_knobs_parse_and_default_off() {
        let mut cfg = Config::preset("tiny").unwrap();
        assert!(!cfg.system.replication.enabled(), "replication is opt-in");
        assert_eq!(cfg.replica_slots(), 0, "disabled policy sizes no slots");
        cfg.set("replicate_top", "2").unwrap();
        assert!(cfg.system.replication.enabled());
        assert_eq!(cfg.replica_slots(), 2);
        cfg.set("replicas", "3").unwrap();
        assert_eq!(cfg.system.replication.replicas, 3);
        cfg.set("replication_hysteresis", "2.0").unwrap();
        assert_eq!(cfg.system.replication.hysteresis, 2.0);
        cfg.set("ewma_alpha", "0.5").unwrap();
        assert_eq!(cfg.system.replication.ewma_alpha, 0.5);
        cfg.validate().unwrap();
        // alias spellings
        cfg.set("top_r", "1").unwrap();
        assert_eq!(cfg.system.replication.top_r, 1);
        cfg.set("hysteresis", "1.25").unwrap();
        assert_eq!(cfg.system.replication.hysteresis, 1.25);
        // replicas < 2 makes the policy inert even with top_r set
        cfg.set("replicas", "1").unwrap();
        assert!(!cfg.system.replication.enabled());
        assert_eq!(cfg.replica_slots(), 0);
        // degenerate values are rejected by validate()
        cfg.set("replicas", "2").unwrap();
        for (k, v) in [("ewma_alpha", "0"), ("ewma_alpha", "1.5"), ("hysteresis", "0.5")] {
            let mut bad = cfg.clone();
            bad.set(k, v).unwrap();
            assert!(bad.validate().is_err(), "{k}={v} must fail validation");
        }
    }

    #[test]
    fn fault_knobs_parse_and_default_off() {
        let mut cfg = Config::preset("tiny").unwrap();
        assert!(!cfg.system.fault.enabled(), "fault injection is opt-in");
        assert_eq!(cfg.system.watchdog_secs, 120, "watchdog default matches the old constant");
        assert_eq!(cfg.system.retry_limit, 0, "fail-fast is the default");
        cfg.set("watchdog_secs", "5").unwrap();
        assert_eq!(cfg.system.watchdog_secs, 5);
        cfg.set("retry_limit", "3").unwrap();
        assert_eq!(cfg.system.retry_limit, 3);
        cfg.set("fault_seed", "42").unwrap();
        assert!(!cfg.system.fault.enabled(), "a seed alone schedules nothing");
        cfg.set("fault_transient_rate", "0.25").unwrap();
        assert!(cfg.system.fault.enabled());
        cfg.set("fault_transient_from", "2").unwrap();
        cfg.set("fault_transient_until", "4").unwrap();
        cfg.set("fault_delay_rate", "0.5").unwrap();
        cfg.set("fault_delay_us", "100").unwrap();
        cfg.set("fault_kill_rank", "1").unwrap();
        cfg.set("fault_kill_epoch", "7").unwrap();
        assert_eq!(cfg.system.fault.seed, 42);
        assert_eq!(cfg.system.fault.transient_rate, 0.25);
        assert_eq!(cfg.system.fault.transient_from, 2);
        assert_eq!(cfg.system.fault.transient_until, 4);
        assert_eq!(cfg.system.fault.kill_rank, Some(1));
        assert_eq!(cfg.system.fault.kill_epoch, 7);
        cfg.validate().unwrap();
        // alias spellings, and "none" clears the kill
        cfg.set("kill_rank", "none").unwrap();
        assert_eq!(cfg.system.fault.kill_rank, None);
        cfg.set("kill_epoch", "3").unwrap();
        assert_eq!(cfg.system.fault.kill_epoch, 3);
        // degenerate values are rejected by validate()
        for (k, v) in [
            ("fault_transient_rate", "1.5"),
            ("fault_transient_rate", "-0.1"),
            ("fault_delay_rate", "nan"),
            ("fault_kill_rank", "9"),
            ("watchdog_secs", "0"),
        ] {
            let mut bad = cfg.clone();
            bad.set(k, v).unwrap();
            assert!(bad.validate().is_err(), "{k}={v} must fail validation");
        }
        // an until below from is rejected (0 stays the open-ended marker)
        let mut bad = cfg.clone();
        bad.set("fault_transient_from", "5").unwrap();
        bad.set("fault_transient_until", "2").unwrap();
        assert!(bad.validate().is_err());
        bad.set("fault_transient_until", "0").unwrap();
        bad.validate().unwrap();
    }

    #[test]
    fn config_file_roundtrip() {
        let dir = std::env::temp_dir().join("flashdmoe_cfg_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("exp.cfg");
        std::fs::write(&p, "preset=tiny\ntokens=256 # more tokens\nranks=2\n").unwrap();
        let cfg = Config::from_file(p.to_str().unwrap()).unwrap();
        assert_eq!(cfg.system.s_rank, 256);
        assert_eq!(cfg.model.h, 64);
    }
}
