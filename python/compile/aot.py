"""AOT compile path: lower the L1/L2 graphs once to HLO *text* artifacts.

Run by ``make artifacts``; Python never executes at Rust runtime. Interchange
format is HLO text (NOT a serialized HloModuleProto): jax >= 0.5 emits protos
with 64-bit instruction ids which xla_extension 0.5.1 (the version behind the
published ``xla`` 0.1.6 crate) rejects; the text parser reassigns ids and
round-trips cleanly.

Emitted per preset (see PRESETS):

  gate          softmax(A @ Wg) over a rank's (S_r, H) tokens -> (S_r, E)
  ffn_block     fused per-tile expert FFN: (C_buf, H) -> (C_buf, H)
  gemm0_tile    t1: relu(A@W1+b1), one (bM, H)x(H, bN) tile
  gemm1_tile    t2: A@W2+b2, one (bM, D)x(D, bN) tile
  combine_tile  t3: acc + scale*x, one (bM, H) tile
  moe_layer     monolithic full-layer reference over all ranks' tokens

plus ``manifest.json`` describing shapes so the Rust ArtifactStore can load
and type-check everything without re-deriving config math.
"""

from __future__ import annotations

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .kernels import combine as combine_k
from .kernels import ffn as ffn_k
from .kernels import gate as gate_k
from .kernels.ref import expert_capacity
from . import model

F32 = jnp.float32


# Preset configs. `default` is the e2e/integration config; `tiny` keeps CI
# and pytest fast; `perf` is the larger shape the perf pass measures.
PRESETS = {
    "tiny": dict(h=64, d=128, e=8, k=2, bm=32, bn=32, ranks=2, s_rank=128, cf=1.0),
    "default": dict(h=256, d=512, e=16, k=2, bm=128, bn=64, ranks=4, s_rank=512, cf=1.0),
    "perf": dict(h=512, d=1024, e=16, k=2, bm=128, bn=64, ranks=4, s_rank=1024, cf=1.0),
}


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


def build_preset(name: str, cfg: dict, out_dir: str) -> dict:
    h, d, e, k = cfg["h"], cfg["d"], cfg["e"], cfg["k"]
    bm, bn, ranks, s_rank = cfg["bm"], cfg["bn"], cfg["ranks"], cfg["s_rank"]
    cap = expert_capacity(s_rank, e, k, cfg["cf"], bm)
    s_total = ranks * s_rank
    c_buf = ranks * cap  # rows an expert owner stages per local expert

    entries = {}

    def emit(art_name, lowered, inputs, outputs):
        fname = f"{name}_{art_name}.hlo.txt"
        text = to_hlo_text(lowered)
        with open(os.path.join(out_dir, fname), "w") as f:
            f.write(text)
        entries[art_name] = {
            "file": fname,
            "inputs": [[n, list(s)] for n, s in inputs],
            "outputs": [[n, list(s)] for n, s in outputs],
        }
        print(f"  {fname:40s} {len(text):>9} chars")

    # gate over one rank's tokens
    emit(
        "gate",
        jax.jit(lambda a, wg: gate_k.gate_scores(a, wg, bm=bm)).lower(
            spec(s_rank, h), spec(h, e)
        ),
        [("a", (s_rank, h)), ("wg", (h, e))],
        [("scores", (s_rank, e))],
    )

    # fused FFN over one local expert's staged buffer (all peers' tiles)
    emit(
        "ffn_block",
        jax.jit(
            lambda x, w1, b1, w2, b2: ffn_k.ffn_block(x, w1, b1, w2, b2, bm=bm)
        ).lower(spec(c_buf, h), spec(h, d), spec(d), spec(d, h), spec(h)),
        [("x", (c_buf, h)), ("w1", (h, d)), ("b1", (d,)), ("w2", (d, h)), ("b2", (h,))],
        [("y", (c_buf, h))],
    )

    # single-tile fused FFN (the paper's per-tile task unit)
    emit(
        "ffn_tile",
        jax.jit(
            lambda x, w1, b1, w2, b2: ffn_k.ffn_block(x, w1, b1, w2, b2, bm=bm)
        ).lower(spec(bm, h), spec(h, d), spec(d), spec(d, h), spec(h)),
        [("x", (bm, h)), ("w1", (h, d)), ("b1", (d,)), ("w2", (d, h)), ("b2", (h,))],
        [("y", (bm, h))],
    )

    # split-mode tiles (GEMM0 / GEMM1 chain)
    emit(
        "gemm0_tile",
        jax.jit(lambda x, w, b: ffn_k.gemm0(x, w, b, bm=bm, bn=bn)).lower(
            spec(bm, h), spec(h, bn), spec(bn)
        ),
        [("x", (bm, h)), ("w1c", (h, bn)), ("b1c", (bn,))],
        [("y", (bm, bn))],
    )
    emit(
        "gemm1_tile",
        jax.jit(lambda x, w, b: ffn_k.gemm1(x, w, b, bm=bm, bn=bn)).lower(
            spec(bm, d), spec(d, bn), spec(bn)
        ),
        [("h", (bm, d)), ("w2c", (d, bn)), ("b2c", (bn,))],
        [("y", (bm, bn))],
    )

    emit(
        "combine_tile",
        jax.jit(lambda acc, x, s: combine_k.combine(acc, x, s, bm=bm)).lower(
            spec(bm, h), spec(bm, h), spec(bm, 1)
        ),
        [("acc", (bm, h)), ("x", (bm, h)), ("scale", (bm, 1))],
        [("y", (bm, h))],
    )

    # training step (paper §5 future work): MoE + readout, MSE, SGD.
    # Differentiable jnp formulation; batch = one rank's tokens.
    from . import train as train_mod

    bsz = s_rank
    cap_b = expert_capacity(bsz, e, k, cfg["cf"], bm)
    step = lambda wg_, w1_, b1_, w2_, b2_, hw_, hb_, x_, y_: train_mod.train_step_flat(
        (wg_, w1_, b1_, w2_, b2_, hw_, hb_), x_, y_,
        h=h, d=d, e=e, k=k, capacity=cap_b, lr=0.05,
    )
    emit(
        "train_step",
        jax.jit(step).lower(
            spec(h, e), spec(e, h, d), spec(e, d), spec(e, d, h), spec(e, h),
            spec(h, 1), spec(1), spec(bsz, h), spec(bsz, 1),
        ),
        [
            ("wg", (h, e)), ("w1", (e, h, d)), ("b1", (e, d)),
            ("w2", (e, d, h)), ("b2", (e, h)), ("head_w", (h, 1)), ("head_b", (1,)),
            ("x", (bsz, h)), ("y", (bsz, 1)),
        ],
        [
            ("loss", (1,)), ("wg", (h, e)), ("w1", (e, h, d)), ("b1", (e, d)),
            ("w2", (e, d, h)), ("b2", (e, h)), ("head_w", (h, 1)), ("head_b", (1,)),
        ],
    )

    # monolithic reference layer over every rank's tokens
    emit(
        "moe_layer",
        jax.jit(
            lambda a, wg, w1, b1, w2, b2: model.moe_layer(
                a, wg, w1, b1, w2, b2, k=k, capacity=cap, s_rank=s_rank, bm=bm
            )
        ).lower(
            spec(s_total, h),
            spec(h, e),
            spec(e, h, d),
            spec(e, d),
            spec(e, d, h),
            spec(e, h),
        ),
        [
            ("a", (s_total, h)),
            ("wg", (h, e)),
            ("w1", (e, h, d)),
            ("b1", (e, d)),
            ("w2", (e, d, h)),
            ("b2", (e, h)),
        ],
        [("out", (s_total, h))],
    )

    return {
        "config": {
            "h": h, "d": d, "e": e, "k": k, "bm": bm, "bn": bn,
            "ranks": ranks, "s_rank": s_rank, "s_total": s_total,
            "capacity": cap, "capacity_factor": cfg["cf"],
        },
        "artifacts": entries,
    }


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out-dir", default="../artifacts")
    p.add_argument(
        "--presets", default="tiny,default", help="comma list or 'all'"
    )
    args = p.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)
    names = list(PRESETS) if args.presets == "all" else args.presets.split(",")

    manifest = {"format": 1, "presets": {}}
    for name in names:
        print(f"preset {name}: {PRESETS[name]}")
        manifest["presets"][name] = build_preset(name, PRESETS[name], args.out_dir)

    path = os.path.join(args.out_dir, "manifest.json")
    with open(path, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote {path}")


if __name__ == "__main__":
    main()
