//! Cost-model calibration: measure real tile-GEMM wall times on this
//! machine and translate them into the simulator's `flops_per_processor`.
//!
//! The simulator's *shape* claims don't depend on absolute FLOP/s, but
//! calibrating keeps virtual latencies in a realistic regime (and the
//! perf pass compares measured coordinator latency against the calibrated
//! flash-engine prediction as a sanity check).

use std::time::Instant;

use crate::config::Config;
use crate::expert::ExpertParams;
use crate::runtime::{ComputeBackend, NativeBackend};
use crate::util::prng::Rng;

/// Calibration output.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Measured fused-FFN tile time (seconds).
    pub ffn_tile_secs: f64,
    /// Implied per-processor FLOP/s.
    pub flops_per_processor: f64,
    /// Gate time for one rank's tokens (seconds).
    pub gate_secs: f64,
    pub backend: &'static str,
}

/// Measure `iters` fused FFN tiles + one gate pass on `backend`.
pub fn calibrate_backend(
    cfg: &Config,
    backend: &dyn ComputeBackend,
    iters: usize,
) -> anyhow::Result<Calibration> {
    let m = &cfg.model;
    let mut rng = Rng::new(0xCA11);
    let ex = ExpertParams {
        w1: rng.normal_vec(m.h * m.d, 0.1),
        b1: rng.normal_vec(m.d, 0.1),
        w2: rng.normal_vec(m.d * m.h, 0.1),
        b2: rng.normal_vec(m.h, 0.1),
    };
    let x = rng.normal_vec(m.bm * m.h, 1.0);
    let mut out = vec![0.0f32; m.bm * m.h];
    let mut scratch = vec![0.0f32; m.bm * m.d];
    // warmup
    backend.ffn_tile(&x, &ex, 0, &mut out, &mut scratch)?;
    let t0 = Instant::now();
    for _ in 0..iters {
        backend.ffn_tile(&x, &ex, 0, &mut out, &mut scratch)?;
    }
    let ffn_tile_secs = t0.elapsed().as_secs_f64() / iters as f64;
    let flops_per_processor = m.ffn_flops(m.bm) / ffn_tile_secs;

    let s = cfg.system.s_rank;
    let a = rng.normal_vec(s * m.h, 1.0);
    let wg = rng.normal_vec(m.h * m.e, 1.0);
    backend.gate_scores(&a, &wg, s)?; // warmup
    let t1 = Instant::now();
    backend.gate_scores(&a, &wg, s)?;
    let gate_secs = t1.elapsed().as_secs_f64();

    Ok(Calibration { ffn_tile_secs, flops_per_processor, gate_secs, backend: backend.name() })
}

/// Calibrate the native backend and write the result into `cfg.cost`.
pub fn apply_native_calibration(cfg: &mut Config, iters: usize) -> anyhow::Result<Calibration> {
    let backend = NativeBackend::from_config(cfg);
    let cal = calibrate_backend(cfg, &backend, iters)?;
    cfg.cost.flops_per_processor = cal.flops_per_processor;
    Ok(cal)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_produces_sane_numbers() {
        let mut cfg = Config::preset("tiny").unwrap();
        let cal = apply_native_calibration(&mut cfg, 3).unwrap();
        assert!(cal.ffn_tile_secs > 0.0);
        // anything from 100 MFLOP/s to 1 TFLOP/s is plausible on CPU
        assert!(
            cal.flops_per_processor > 1e8 && cal.flops_per_processor < 1e12,
            "implausible {}",
            cal.flops_per_processor
        );
        assert_eq!(cfg.cost.flops_per_processor, cal.flops_per_processor);
    }
}
