//! Multi-model residency: the fingerprinted [`ModelRegistry`] that lets
//! one persistent engine host several expert sets — full models and
//! LoRA-style delta variants — sharing a single packed-weight cache
//! (ROADMAP item 5, mirroring the pjrt-rs executable-lifecycle idioms:
//! content fingerprints keying a serialized-program cache).
//!
//! The engine is started with one model (id **0**, the *anchor*: its
//! parameters, placement and load tracker live where they always did, so
//! the single-model path is bitwise-identical to a registry-free engine).
//! Additional models occupy ids `1..max_models`
//! (`SystemConfig::max_models`, knob `max_models`) and are installed or
//! evicted only at the engine's epoch-fenced quiet point — exactly like a
//! replication rebalance — so no in-flight pass ever observes a
//! half-registered model.
//!
//! Three residency flavours, audited by the backend's `pack_count()`:
//!
//! * **fresh base** — a new expert set; packed once into its own key
//!   region of the shared cache (`key_base = id × E`), costing a full
//!   pack and full parameter bytes;
//! * **deduped base** — re-registering weights whose content fingerprint
//!   (FNV-1a over every parameter's bit pattern) matches an already
//!   resident model; shares that model's packed entries — **zero** new
//!   packs, zero incremental bytes;
//! * **delta variant** — a [`DeltaSet`] of low-rank per-expert updates
//!   over a resident base: the base's packed panels serve the main GEMMs
//!   and the delta is applied in the **epilogue** of each FFN tile, so a
//!   resident variant costs delta bytes, never a repack.
//!
//! Every model gets its *own* [`Placement`] + EWMA [`LoadTracker`]
//! (replication decisions are per-model — a hot expert in model A says
//! nothing about model B), while all models share the engine's symmetric
//! heap: each model owns a contiguous band of expert slots
//! (`e_base(id) .. e_base(id) + per-model slots`), so the write-validity
//! rules, announcements and flag indexing carry over with a constant slot
//! offset and **no** cross-model cell aliasing.

use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Result};

use crate::config::Config;
use crate::expert::ModelParams;
use crate::placement::{LoadTracker, Placement};
use crate::util::prng::Rng;

/// Identifier of a resident model. Id 0 is the engine's anchor model
/// (the parameters `MoeEngine::start` was given); ids `1..max_models`
/// are registry slots.
pub type ModelId = usize;

/// What a registered model is, structurally.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ModelKind {
    /// A full expert set with its own packed weights (or a fingerprint
    /// dedup onto another resident base's packed weights).
    Base,
    /// A LoRA-style delta over a resident base model: shares the base's
    /// packed weights, applies its low-rank update in the FFN epilogue.
    Delta {
        /// The resident base model the delta is relative to.
        base: ModelId,
    },
}

/// The caller's receipt for a registered model: identity, content
/// fingerprint, and what residency actually cost.
#[derive(Clone, Debug)]
pub struct ModelHandle {
    /// Registry slot the model occupies (`1..max_models`; the anchor
    /// model's implicit handle has id 0).
    pub id: ModelId,
    /// FNV-1a content hash over every parameter bit pattern (shape
    /// included). Two registrations with equal fingerprints share one
    /// packed-cache region.
    pub fingerprint: u64,
    pub kind: ModelKind,
    /// True iff registration found an already-resident model with the
    /// same fingerprint and shared its packed weights (zero new packs).
    pub deduped: bool,
    /// Incremental bytes this registration added to the engine's
    /// resident weight footprint: full parameter bytes for a fresh base,
    /// 0 for a dedup, `DeltaSet::bytes()` for a delta variant.
    pub resident_bytes: usize,
}

/// One expert's low-rank update in a [`DeltaSet`]: W2 gains the rank-`r`
/// product `A2·B2` and b2 gains `db2`, so the expert's output row becomes
/// `relu(x·W1 + b1)·(W2 + A2·B2) + (b2 + db2)` — computed as the base
/// FFN plus an epilogue term `(mid·A2)·B2 + db2` on the already-resident
/// packed base panels.
#[derive(Clone, Debug)]
pub struct ExpertDelta {
    /// (D, r) row-major.
    pub a2: Vec<f32>,
    /// (r, H) row-major.
    pub b2: Vec<f32>,
    /// (H,) bias delta.
    pub db2: Vec<f32>,
}

/// A LoRA-style low-rank delta over a full base model: one
/// [`ExpertDelta`] per expert. No gate delta — a variant routes with its
/// base's gate (per-expert output adaptation is the LoRA serving shape).
#[derive(Clone, Debug)]
pub struct DeltaSet {
    /// Low-rank dimension r (≥ 1, typically ≪ D).
    pub rank: usize,
    /// One delta per global expert, length E.
    pub experts: Vec<ExpertDelta>,
    pub h: usize,
    pub d: usize,
}

impl DeltaSet {
    /// Deterministically generate a delta set from `seed` (independent of
    /// the base-weight PRNG streams). `scale` sets the update magnitude.
    pub fn generate(cfg: &Config, seed: u64, rank: usize, scale: f32) -> Self {
        let (h, d, e) = (cfg.model.h, cfg.model.d, cfg.model.e);
        let rank = rank.max(1);
        let base = Rng::new(seed);
        let experts = (0..e)
            .map(|ex| {
                let mut r = base.fork(0xDE17_A000 + ex as u64);
                ExpertDelta {
                    a2: r.normal_vec(d * rank, scale),
                    b2: r.normal_vec(rank * h, scale),
                    db2: r.normal_vec(h, scale),
                }
            })
            .collect();
        Self { rank, experts, h, d }
    }

    /// Resident footprint of the delta in bytes — what a variant costs
    /// next to its base's shared packed weights.
    pub fn bytes(&self) -> usize {
        self.experts
            .iter()
            .map(|e| (e.a2.len() + e.b2.len() + e.db2.len()) * std::mem::size_of::<f32>())
            .sum()
    }

    /// Apply `expert`'s delta to `rows` output rows in the FFN epilogue:
    /// `out[row] += (mid[row]·A2)·B2 + db2`, with `mid` the (rows, D)
    /// post-ReLU GEMM0 activations and `out` the (rows, H) tile output,
    /// both row-major and contiguous.
    pub fn apply_rows(&self, expert: usize, mid: &[f32], out: &mut [f32], rows: usize) {
        let (h, d, r) = (self.h, self.d, self.rank);
        debug_assert!(mid.len() >= rows * d && out.len() >= rows * h);
        let ex = &self.experts[expert];
        let mut tmp = vec![0.0f32; r];
        for row in 0..rows {
            let m = &mid[row * d..row * d + d];
            for t in tmp.iter_mut() {
                *t = 0.0;
            }
            for (dd, &mv) in m.iter().enumerate() {
                if mv == 0.0 {
                    continue; // post-ReLU activations are sparse
                }
                let a = &ex.a2[dd * r..dd * r + r];
                for (t, &av) in tmp.iter_mut().zip(a) {
                    *t += mv * av;
                }
            }
            let o = &mut out[row * h..row * h + h];
            for (j, &tv) in tmp.iter().enumerate() {
                if tv == 0.0 {
                    continue;
                }
                let b = &ex.b2[j * h..j * h + h];
                for (ov, &bv) in o.iter_mut().zip(b) {
                    *ov += tv * bv;
                }
            }
            for (ov, &bv) in o.iter_mut().zip(&ex.db2) {
                *ov += bv;
            }
        }
    }

    fn validate(&self, cfg: &Config) -> Result<()> {
        let m = &cfg.model;
        ensure!(
            self.h == m.h && self.d == m.d && self.experts.len() == m.e,
            "delta shape (h={}, d={}, e={}) does not match the engine config \
             (h={}, d={}, e={})",
            self.h,
            self.d,
            self.experts.len(),
            m.h,
            m.d,
            m.e
        );
        ensure!(self.rank >= 1, "delta rank must be >= 1");
        for (i, e) in self.experts.iter().enumerate() {
            ensure!(
                e.a2.len() == self.d * self.rank
                    && e.b2.len() == self.rank * self.h
                    && e.db2.len() == self.h,
                "expert {i} delta tensors do not match (d={}, r={}, h={})",
                self.d,
                self.rank,
                self.h
            );
        }
        Ok(())
    }
}

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

fn fnv1a_bytes(mut acc: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        acc ^= b as u64;
        acc = acc.wrapping_mul(FNV_PRIME);
    }
    acc
}

fn fnv1a_f32s(mut acc: u64, vals: &[f32]) -> u64 {
    for &v in vals {
        acc = fnv1a_bytes(acc, &v.to_bits().to_le_bytes());
    }
    acc
}

/// Content fingerprint of a full parameter set: FNV-1a over the shape and
/// every weight's exact bit pattern (so `-0.0` vs `0.0` and NaN payloads
/// all distinguish — the packed panels are bit-derived from these).
pub fn fingerprint_params(p: &ModelParams) -> u64 {
    let mut acc = FNV_OFFSET;
    for dim in [p.h, p.d, p.experts.len()] {
        acc = fnv1a_bytes(acc, &(dim as u64).to_le_bytes());
    }
    acc = fnv1a_f32s(acc, &p.wg);
    for ex in &p.experts {
        acc = fnv1a_f32s(acc, &ex.w1);
        acc = fnv1a_f32s(acc, &ex.b1);
        acc = fnv1a_f32s(acc, &ex.w2);
        acc = fnv1a_f32s(acc, &ex.b2);
    }
    acc
}

fn params_bytes(p: &ModelParams) -> usize {
    p.size_bytes()
}

/// One resident registry model (ids ≥ 1): the pass-time snapshot sources
/// for gate/dispatch/compute plus the per-model replication state.
pub struct ModelEntry {
    pub handle: ModelHandle,
    /// Full parameters the model gates and computes with. For a dedup or
    /// delta registration this is the *base's* `Arc` — no copy.
    pub params: Arc<ModelParams>,
    /// The low-rank epilogue update, present only for delta variants.
    pub delta: Option<Arc<DeltaSet>>,
    /// Base offset into the shared packed-weight cache: expert `e` of
    /// this model is served by cache key `key_base + e`. Equal to the
    /// dedup/delta target's `key_base` when weights are shared.
    pub key_base: usize,
    /// This model's expert→location map (installed/swap-fenced by the
    /// engine exactly like the anchor model's).
    pub placement: Mutex<Arc<Placement>>,
    /// This model's EWMA offered-load tracker.
    pub tracker: Mutex<LoadTracker>,
}

/// The engine's model table: slot bookkeeping, fingerprint dedup, and
/// per-model placement/tracker state for ids `1..max_models`. The anchor
/// model (id 0) lives in the engine's legacy fields; the registry records
/// only its fingerprint (for dedup) and parameter bytes (for footprint
/// accounting). All mutation happens at the engine's epoch-fenced quiet
/// point, so pass-time reads see a stable table.
pub struct ModelRegistry {
    max_models: usize,
    e: usize,
    ranks: usize,
    replica_slots: usize,
    ewma_alpha: f64,
    /// Heap expert-slot band width of one model (owned + replica slots).
    per_model_slots: usize,
    anchor_fingerprint: u64,
    anchor_bytes: usize,
    anchor_params: Arc<ModelParams>,
    /// `entries[id - 1]` for ids `1..max_models`.
    entries: Mutex<Vec<Option<Arc<ModelEntry>>>>,
}

impl ModelRegistry {
    /// Build the registry around the engine's anchor model (id 0).
    pub fn new(cfg: &Config, anchor: Arc<ModelParams>) -> Self {
        let max_models = cfg.system.max_models.max(1);
        Self {
            max_models,
            e: cfg.model.e,
            ranks: cfg.system.ranks,
            replica_slots: cfg.replica_slots(),
            ewma_alpha: cfg.system.replication.ewma_alpha,
            per_model_slots: cfg.local_experts() + cfg.replica_slots(),
            anchor_fingerprint: fingerprint_params(&anchor),
            anchor_bytes: params_bytes(&anchor),
            anchor_params: anchor,
            entries: Mutex::new(vec![None; max_models.saturating_sub(1)]),
        }
    }

    pub fn max_models(&self) -> usize {
        self.max_models
    }

    /// First expert slot of `model`'s band in the symmetric heap's
    /// (multiplied) expert dimension.
    pub fn e_base(&self, model: ModelId) -> usize {
        model * self.per_model_slots
    }

    /// Is `model` currently resident? (The anchor always is.)
    pub fn is_resident(&self, model: ModelId) -> bool {
        model == 0
            || (model < self.max_models
                && self.entries.lock().unwrap()[model - 1].is_some())
    }

    /// Resident model ids, ascending (always starts with 0).
    pub fn resident_models(&self) -> Vec<ModelId> {
        let entries = self.entries.lock().unwrap();
        std::iter::once(0)
            .chain(entries.iter().enumerate().filter_map(|(i, e)| e.as_ref().map(|_| i + 1)))
            .collect()
    }

    /// The registry entry for a non-anchor resident model.
    pub fn entry(&self, model: ModelId) -> Option<Arc<ModelEntry>> {
        if model == 0 || model >= self.max_models {
            return None;
        }
        self.entries.lock().unwrap()[model - 1].clone()
    }

    /// Register a full expert set. `pack(key_base)` is invoked — before
    /// the entry becomes visible — exactly when fresh packed panels are
    /// needed; a fingerprint match against the anchor or any resident
    /// base instead shares that model's packed region (zero new packs).
    /// Caller must hold the engine's quiet fence.
    pub fn register_base<F>(
        &self,
        cfg: &Config,
        params: Arc<ModelParams>,
        pack: F,
    ) -> Result<ModelHandle>
    where
        F: FnOnce(usize) -> Result<()>,
    {
        let m = &cfg.model;
        ensure!(
            params.h == m.h && params.d == m.d && params.experts.len() == m.e,
            "model shape (h={}, d={}, e={}) does not match the engine config \
             (h={}, d={}, e={}): all resident models share one architecture",
            params.h,
            params.d,
            params.experts.len(),
            m.h,
            m.d,
            m.e
        );
        let fingerprint = fingerprint_params(&params);
        let mut entries = self.entries.lock().unwrap();
        let Some(slot) = entries.iter().position(|e| e.is_none()) else {
            bail!(
                "model registry is full ({} of max_models={} slots resident): \
                 evict a model or raise the max_models knob before engine start",
                self.max_models,
                self.max_models
            );
        };
        let id = slot + 1;
        // Fingerprint dedup: share the anchor's (or a resident base's)
        // packed region and parameter Arc instead of packing again.
        let dedup = if fingerprint == self.anchor_fingerprint {
            Some((0usize, self.anchor_params.clone()))
        } else {
            entries.iter().flatten().find(|e| e.handle.fingerprint == fingerprint).map(|e| {
                (e.key_base, e.params.clone())
            })
        };
        let (key_base, params, deduped, resident_bytes) = match dedup {
            Some((kb, shared)) => (kb, shared, true, 0),
            None => {
                let kb = id * self.e;
                pack(kb)?;
                let bytes = params_bytes(&params);
                (kb, params, false, bytes)
            }
        };
        let handle = ModelHandle {
            id,
            fingerprint,
            kind: ModelKind::Base,
            deduped,
            resident_bytes,
        };
        entries[slot] = Some(Arc::new(ModelEntry {
            handle: handle.clone(),
            params,
            delta: None,
            key_base,
            placement: Mutex::new(Arc::new(Placement::balanced(
                self.e,
                self.ranks,
                self.replica_slots,
            ))),
            tracker: Mutex::new(LoadTracker::new(self.e, self.ranks, self.ewma_alpha)),
        }));
        Ok(handle)
    }

    /// Register a LoRA-style delta variant over resident base model
    /// `base`: shares the base's parameters and packed weights, stores
    /// only the delta (applied in the FFN epilogue at pass time). Caller
    /// must hold the engine's quiet fence.
    pub fn register_delta(
        &self,
        cfg: &Config,
        base: ModelId,
        delta: Arc<DeltaSet>,
    ) -> Result<ModelHandle> {
        delta.validate(cfg)?;
        let mut entries = self.entries.lock().unwrap();
        let (base_params, base_key) = if base == 0 {
            (self.anchor_params.clone(), 0)
        } else {
            let e = entries
                .get(base.wrapping_sub(1))
                .and_then(|e| e.as_ref())
                .ok_or_else(|| anyhow::anyhow!("delta base model {base} is not resident"))?;
            ensure!(
                e.delta.is_none(),
                "delta base model {base} is itself a delta variant: stack onto its base instead"
            );
            (e.params.clone(), e.key_base)
        };
        let Some(slot) = entries.iter().position(|e| e.is_none()) else {
            bail!(
                "model registry is full ({} slots): evict a model before registering the delta",
                self.max_models
            );
        };
        let id = slot + 1;
        let resident_bytes = delta.bytes();
        // Fingerprint the *variant*: the base's content hash folded with
        // the delta tensors, so two identical variants compare equal.
        let mut fp = if base == 0 {
            self.anchor_fingerprint
        } else {
            entries[base - 1].as_ref().unwrap().handle.fingerprint
        };
        fp = fnv1a_bytes(fp, &(delta.rank as u64).to_le_bytes());
        for ex in &delta.experts {
            fp = fnv1a_f32s(fp, &ex.a2);
            fp = fnv1a_f32s(fp, &ex.b2);
            fp = fnv1a_f32s(fp, &ex.db2);
        }
        let handle = ModelHandle {
            id,
            fingerprint: fp,
            kind: ModelKind::Delta { base },
            deduped: true, // shares the base's packed weights by construction
            resident_bytes,
        };
        entries[slot] = Some(Arc::new(ModelEntry {
            handle: handle.clone(),
            params: base_params,
            delta: Some(delta),
            key_base: base_key,
            placement: Mutex::new(Arc::new(Placement::balanced(
                self.e,
                self.ranks,
                self.replica_slots,
            ))),
            tracker: Mutex::new(LoadTracker::new(self.e, self.ranks, self.ewma_alpha)),
        }));
        Ok(handle)
    }

    /// Evict a resident model, freeing its registry slot (its heap band
    /// simply goes quiet). The anchor (id 0) is not evictable, and a
    /// model other resident models depend on (a delta's base, or the
    /// pack-region owner of a deduped registration) must outlive its
    /// dependents. Caller must hold the engine's quiet fence.
    pub fn evict(&self, model: ModelId) -> Result<()> {
        ensure!(model != 0, "the anchor model (id 0) cannot be evicted");
        let mut entries = self.entries.lock().unwrap();
        let slot = model
            .checked_sub(1)
            .filter(|&s| s < entries.len())
            .ok_or_else(|| anyhow::anyhow!("model id {model} out of range"))?;
        let Some(victim) = entries[slot].as_ref() else {
            bail!("model {model} is not resident");
        };
        let victim_key = victim.key_base;
        for (i, e) in entries.iter().enumerate() {
            let Some(e) = e.as_ref() else { continue };
            if i == slot {
                continue;
            }
            if e.handle.kind == (ModelKind::Delta { base: model }) {
                bail!(
                    "model {model} has a resident delta variant (model {}): evict it first",
                    i + 1
                );
            }
            if e.handle.deduped && e.key_base == victim_key && victim_key != 0 {
                bail!(
                    "model {} shares model {model}'s packed weights: evict it first",
                    i + 1
                );
            }
        }
        entries[slot] = None;
        Ok(())
    }

    /// Total resident weight bytes across all models, counting every
    /// shared packed region once: anchor params + each fresh base's
    /// params + each delta's tensors. This is the footprint the
    /// multi-model bench compares against N dedicated engines.
    pub fn resident_bytes(&self) -> usize {
        let entries = self.entries.lock().unwrap();
        self.anchor_bytes
            + entries
                .iter()
                .flatten()
                .map(|e| e.handle.resident_bytes)
                .sum::<usize>()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Config;

    fn cfg_with_models(n: usize) -> Config {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.set("max_models", &n.to_string()).unwrap();
        cfg
    }

    #[test]
    fn fingerprints_are_content_addressed() {
        let cfg = Config::preset("tiny").unwrap();
        let a = ModelParams::generate(&cfg, 7);
        let b = ModelParams::generate(&cfg, 7);
        let c = ModelParams::generate(&cfg, 8);
        assert_eq!(fingerprint_params(&a), fingerprint_params(&b));
        assert_ne!(fingerprint_params(&a), fingerprint_params(&c));
        // a single flipped bit changes the hash
        let mut d = a.clone();
        d.experts[0].w2[3] += 1.0;
        assert_ne!(fingerprint_params(&a), fingerprint_params(&d));
    }

    #[test]
    fn register_dedups_identical_weights_and_packs_fresh_ones() {
        let cfg = cfg_with_models(4);
        let anchor = Arc::new(ModelParams::generate(&cfg, 42));
        let reg = ModelRegistry::new(&cfg, anchor.clone());
        assert!(reg.is_resident(0));
        assert_eq!(reg.resident_models(), vec![0]);

        // identical weights: dedup onto the anchor, no pack callback
        let same = Arc::new(ModelParams::generate(&cfg, 42));
        let h1 = reg
            .register_base(&cfg, same, |_| panic!("dedup must not pack"))
            .unwrap();
        assert_eq!(h1.id, 1);
        assert!(h1.deduped);
        assert_eq!(h1.resident_bytes, 0);
        assert_eq!(reg.entry(1).unwrap().key_base, 0, "shares the anchor's region");

        // fresh weights: packed once at its own key base
        let fresh = Arc::new(ModelParams::generate(&cfg, 99));
        let mut packed_at = None;
        let h2 = reg
            .register_base(&cfg, fresh.clone(), |kb| {
                packed_at = Some(kb);
                Ok(())
            })
            .unwrap();
        assert_eq!(h2.id, 2);
        assert!(!h2.deduped);
        assert_eq!(packed_at, Some(2 * cfg.model.e));
        assert_eq!(h2.resident_bytes, fresh.num_params() * 4);
        assert_eq!(reg.resident_models(), vec![0, 1, 2]);
        assert_eq!(
            reg.resident_bytes(),
            anchor.num_params() * 4 + fresh.num_params() * 4,
            "dedup adds zero resident bytes"
        );

        // re-registering the fresh model dedups onto *it*, not the anchor
        let again = Arc::new(ModelParams::generate(&cfg, 99));
        let h3 = reg
            .register_base(&cfg, again, |_| panic!("dedup must not pack"))
            .unwrap();
        assert!(h3.deduped);
        assert_eq!(reg.entry(3).unwrap().key_base, 2 * cfg.model.e);
        // the registry is now full
        let more = Arc::new(ModelParams::generate(&cfg, 123));
        assert!(reg.register_base(&cfg, more, |_| Ok(())).is_err());
    }

    #[test]
    fn delta_variants_cost_delta_bytes_and_guard_eviction() {
        let cfg = cfg_with_models(3);
        let anchor = Arc::new(ModelParams::generate(&cfg, 1));
        let reg = ModelRegistry::new(&cfg, anchor.clone());
        let delta = Arc::new(DeltaSet::generate(&cfg, 7, 2, 0.05));
        let h = reg.register_delta(&cfg, 0, delta.clone()).unwrap();
        assert_eq!(h.id, 1);
        assert_eq!(h.kind, ModelKind::Delta { base: 0 });
        assert_eq!(h.resident_bytes, delta.bytes());
        assert!(
            delta.bytes() < anchor.num_params() * 4,
            "a delta must cost less than a full parameter set"
        );
        let entry = reg.entry(1).unwrap();
        assert_eq!(entry.key_base, 0, "delta serves from the base's packed region");
        assert!(entry.delta.is_some());

        // a fresh base, then a delta over it: eviction order is enforced
        let fresh = Arc::new(ModelParams::generate(&cfg, 2));
        let hb = reg.register_base(&cfg, fresh, |_| Ok(())).unwrap();
        // registry now holds anchor + delta(1) + base(2); it is full
        assert!(reg.register_delta(&cfg, hb.id, delta.clone()).is_err(), "full");
        assert!(reg.evict(0).is_err(), "anchor is not evictable");
        reg.evict(hb.id).unwrap();
        let hd2 = reg.register_delta(&cfg, 0, delta.clone()).unwrap();
        assert_eq!(hd2.id, 2, "evicted slot is reused");
        // base 0 has dependents but is the anchor; a registry base with a
        // dependent delta refuses eviction
        reg.evict(2).unwrap();
        reg.evict(1).unwrap(); // free both slots for the base+delta pair
        let hb2 = reg.register_base(&cfg, Arc::new(ModelParams::generate(&cfg, 3)), |_| Ok(()))
            .unwrap();
        let hd3 = reg.register_delta(&cfg, hb2.id, delta).unwrap();
        assert!(reg.evict(hb2.id).is_err(), "delta depends on its base");
        reg.evict(hd3.id).unwrap();
        reg.evict(hb2.id).unwrap();
        assert_eq!(reg.resident_models(), vec![0]);
    }

    #[test]
    fn delta_epilogue_matches_materialized_weights() {
        // out_base + epilogue == FFN with W2 + A2·B2 and b2 + db2
        let cfg = Config::preset("tiny").unwrap();
        let (h, d) = (cfg.model.h, cfg.model.d);
        let params = ModelParams::generate(&cfg, 5);
        let delta = DeltaSet::generate(&cfg, 9, 2, 0.1);
        let ex = &params.experts[1];
        let de = &delta.experts[1];
        let rows = 3;
        let mut rng = Rng::new(11);
        let x = rng.normal_vec(rows * h, 1.0);
        // base FFN (reference path) + captured mid
        let mut mid = vec![0.0f32; rows * d];
        let mut out = vec![0.0f32; rows * h];
        crate::gemm::ffn(&x, &ex.w1, &ex.b1, &ex.w2, &ex.b2, &mut out, &mut mid, rows, h, d);
        delta.apply_rows(1, &mid, &mut out, rows);
        // materialized variant weights
        let mut w2m = ex.w2.clone();
        for dd in 0..d {
            for hh in 0..h {
                let mut acc = 0.0f32;
                for j in 0..delta.rank {
                    acc += de.a2[dd * delta.rank + j] * de.b2[j * h + hh];
                }
                w2m[dd * h + hh] += acc;
            }
        }
        let b2m: Vec<f32> = ex.b2.iter().zip(&de.db2).map(|(a, b)| a + b).collect();
        let mut want = vec![0.0f32; rows * h];
        let mut scratch = vec![0.0f32; rows * d];
        crate::gemm::ffn(&x, &ex.w1, &ex.b1, &w2m, &b2m, &mut want, &mut scratch, rows, h, d);
        let diff = crate::util::stats::max_abs_diff(&out, &want);
        assert!(diff < 1e-4, "epilogue diverged from materialized variant: {diff}");
    }

    #[test]
    fn e_base_bands_do_not_overlap() {
        let cfg = cfg_with_models(3);
        let reg = ModelRegistry::new(&cfg, Arc::new(ModelParams::generate(&cfg, 1)));
        let band = cfg.local_experts() + cfg.replica_slots();
        for m in 0..3 {
            assert_eq!(reg.e_base(m), m * band);
        }
    }
}
