//! Microbench: tile-level compute on both backends — the calibration
//! source for the simulator's cost model and the §Perf L3 hot-path
//! baseline. Prints GFLOP/s per tile shape for the native blocked GEMM
//! and (when artifacts exist) the XLA/PJRT Pallas kernels.

use std::time::Instant;

use flashdmoe::config::Config;
use flashdmoe::expert::ExpertParams;
use flashdmoe::runtime::{ArtifactStore, ComputeBackend, NativeBackend, XlaBackend};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::{fmt_time, Table};

fn bench_backend(name: &str, cfg: &Config, be: &dyn ComputeBackend, iters: usize, t: &mut Table) {
    let m = &cfg.model;
    let mut rng = Rng::new(1);
    let ex = ExpertParams {
        w1: rng.normal_vec(m.h * m.d, 0.1),
        b1: rng.normal_vec(m.d, 0.1),
        w2: rng.normal_vec(m.d * m.h, 0.1),
        b2: rng.normal_vec(m.h, 0.1),
    };
    let x = rng.normal_vec(m.bm * m.h, 1.0);
    let mut out = vec![0.0f32; m.bm * m.h];
    let mut scratch = vec![0.0f32; m.bm * m.d];

    be.ffn_tile(&x, &ex, 0, &mut out, &mut scratch).unwrap(); // warmup
    let t0 = Instant::now();
    for _ in 0..iters {
        be.ffn_tile(&x, &ex, 0, &mut out, &mut scratch).unwrap();
    }
    let per = t0.elapsed().as_secs_f64() / iters as f64;
    let gflops = m.ffn_flops(m.bm) / per / 1e9;

    // gate
    let s = cfg.system.s_rank;
    let a = rng.normal_vec(s * m.h, 1.0);
    let wg = rng.normal_vec(m.h * m.e, 1.0);
    be.gate_scores(&a, &wg, s).unwrap();
    let t1 = Instant::now();
    for _ in 0..iters {
        be.gate_scores(&a, &wg, s).unwrap();
    }
    let gate = t1.elapsed().as_secs_f64() / iters as f64;

    t.row(&[
        name.to_string(),
        format!("{}x{}x{}", m.bm, m.h, m.d),
        fmt_time(per),
        format!("{gflops:.2}"),
        fmt_time(gate),
    ]);
}

fn main() {
    let iters: usize = std::env::var("ITERS").ok().and_then(|v| v.parse().ok()).unwrap_or(30);
    let mut t = Table::new(&["backend", "tile (bM,H,D)", "ffn_tile", "GFLOP/s", "gate"]);
    for preset in ["tiny", "default", "perf"] {
        let cfg = Config::preset(preset).unwrap();
        let native = NativeBackend::from_config(&cfg);
        bench_backend(&format!("native/{preset}"), &cfg, &native, iters, &mut t);
        let dir = ArtifactStore::default_dir();
        if preset != "perf" && ArtifactStore::available(&dir) {
            if let Ok(store) = ArtifactStore::load(&dir, preset) {
                let xla = XlaBackend::new(store);
                bench_backend(&format!("xla/{preset}"), &cfg, &xla, iters, &mut t);
            }
        }
    }
    println!("## Microbench — tile compute per backend (calibration source)\n");
    println!("{}", t.render());
}
