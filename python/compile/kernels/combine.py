"""L1 Pallas kernel: the expert-combine task t3 (paper §3.1).

  t3 = (M, hadamard, id):  C <- A ⊙ S + C

i.e. a scale-and-accumulate of an expert-output tile into the token output
matrix, where S broadcasts a per-token combine weight g/C_i across the
embedding dimension. One grid step handles one (bM, H) tile.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _combine_kernel(acc_ref, x_ref, scale_ref, out_ref):
    out_ref[...] = acc_ref[...] + x_ref[...] * scale_ref[...]


@functools.partial(jax.jit, static_argnames=("bm",))
def combine(acc: jax.Array, x: jax.Array, scale: jax.Array, bm: int = 128):
    """acc + scale * x with acc, x: (M, H); scale: (M, 1) -> (M, H) f32."""
    m, h = acc.shape
    assert x.shape == (m, h) and scale.shape == (m, 1)
    assert m % bm == 0, f"M={m} not a multiple of bm={bm}"
    return pl.pallas_call(
        _combine_kernel,
        grid=(m // bm,),
        in_specs=[
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((bm, h), lambda i: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((bm, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((m, h), jnp.float32),
        interpret=True,
    )(acc.astype(jnp.float32), x.astype(jnp.float32), scale.astype(jnp.float32))
