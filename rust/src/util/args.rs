//! Tiny CLI argument parser (clap is unavailable offline).
//!
//! Supports `--key value`, `--key=value`, boolean `--flag`, and positional
//! arguments; generates usage text from registered options.

use std::collections::BTreeMap;

use anyhow::{bail, Context, Result};

/// Declarative spec for one option.
#[derive(Clone, Debug)]
struct Opt {
    name: String,
    help: String,
    default: Option<String>,
    is_flag: bool,
}

/// Parsed command-line arguments against a declared spec.
pub struct Args {
    program: String,
    about: String,
    opts: Vec<Opt>,
    values: BTreeMap<String, String>,
    positionals: Vec<String>,
}

impl Args {
    pub fn new(program: &str, about: &str) -> Self {
        Self {
            program: program.to_string(),
            about: about.to_string(),
            opts: Vec::new(),
            values: BTreeMap::new(),
            positionals: Vec::new(),
        }
    }

    /// Declare an option with a default value.
    pub fn opt(mut self, name: &str, default: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some(default.to_string()),
            is_flag: false,
        });
        self
    }

    /// Declare a required option (no default).
    pub fn req(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: None,
            is_flag: false,
        });
        self
    }

    /// Declare a boolean flag (defaults to false).
    pub fn flag(mut self, name: &str, help: &str) -> Self {
        self.opts.push(Opt {
            name: name.to_string(),
            help: help.to_string(),
            default: Some("false".to_string()),
            is_flag: true,
        });
        self
    }

    pub fn usage(&self) -> String {
        let mut out = format!("{} — {}\n\nOptions:\n", self.program, self.about);
        for o in &self.opts {
            let default = o
                .default
                .as_ref()
                .map(|d| format!(" [default: {d}]"))
                .unwrap_or_else(|| " (required)".to_string());
            out.push_str(&format!("  --{:<18} {}{}\n", o.name, o.help, default));
        }
        out
    }

    /// Parse an argv slice (without the program name).
    pub fn parse(mut self, argv: &[String]) -> Result<Self> {
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if a == "--help" || a == "-h" {
                println!("{}", self.usage());
                std::process::exit(0);
            }
            if let Some(rest) = a.strip_prefix("--") {
                let (key, inline_val) = match rest.split_once('=') {
                    Some((k, v)) => (k.to_string(), Some(v.to_string())),
                    None => (rest.to_string(), None),
                };
                let opt = self
                    .opts
                    .iter()
                    .find(|o| o.name == key)
                    .with_context(|| format!("unknown option --{key}\n{}", self.usage()))?
                    .clone();
                let val = if opt.is_flag && inline_val.is_none() {
                    "true".to_string()
                } else if let Some(v) = inline_val {
                    v
                } else {
                    i += 1;
                    argv.get(i)
                        .with_context(|| format!("--{key} requires a value"))?
                        .clone()
                };
                self.values.insert(key, val);
            } else {
                self.positionals.push(a.clone());
            }
            i += 1;
        }
        // check required
        for o in &self.opts {
            if o.default.is_none() && !self.values.contains_key(&o.name) {
                bail!("missing required option --{}\n{}", o.name, self.usage());
            }
        }
        Ok(self)
    }

    pub fn get(&self, name: &str) -> String {
        if let Some(v) = self.values.get(name) {
            return v.clone();
        }
        self.opts
            .iter()
            .find(|o| o.name == name)
            .and_then(|o| o.default.clone())
            .unwrap_or_else(|| panic!("option --{name} was never declared"))
    }

    pub fn get_usize(&self, name: &str) -> Result<usize> {
        self.get(name).parse().with_context(|| format!("--{name} must be an integer"))
    }

    pub fn get_f64(&self, name: &str) -> Result<f64> {
        self.get(name).parse().with_context(|| format!("--{name} must be a number"))
    }

    pub fn get_bool(&self, name: &str) -> bool {
        matches!(self.get(name).as_str(), "true" | "1" | "yes")
    }

    pub fn positionals(&self) -> &[String] {
        &self.positionals
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn argv(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = Args::new("t", "test")
            .opt("tokens", "1024", "tokens per rank")
            .flag("verbose", "chatty")
            .parse(&argv(&["--tokens", "4096", "--verbose", "pos1"]))
            .unwrap();
        assert_eq!(a.get_usize("tokens").unwrap(), 4096);
        assert!(a.get_bool("verbose"));
        assert_eq!(a.positionals(), &["pos1".to_string()]);
    }

    #[test]
    fn equals_syntax_and_defaults() {
        let a = Args::new("t", "test")
            .opt("ranks", "8", "world size")
            .parse(&argv(&["--ranks=2"]))
            .unwrap();
        assert_eq!(a.get_usize("ranks").unwrap(), 2);
        let b = Args::new("t", "test").opt("ranks", "8", "world size").parse(&[]).unwrap();
        assert_eq!(b.get_usize("ranks").unwrap(), 8);
    }

    #[test]
    fn required_missing_errors() {
        let r = Args::new("t", "test").req("model", "model path").parse(&[]);
        assert!(r.is_err());
    }

    #[test]
    fn unknown_option_errors() {
        let r = Args::new("t", "test").parse(&argv(&["--nope", "1"]));
        assert!(r.is_err());
    }
}
