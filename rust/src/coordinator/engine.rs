//! The persistent `MoeEngine`: the paper's "launch once, stay resident"
//! operator contract made into the public API.
//!
//! [`MoeEngine::start`] brings up every rank's actor group (subscriber
//! thread + resident processor workers) exactly once — the
//! launch-equivalent count in [`EngineMetrics`] is 1 for the engine's
//! whole lifetime. A forward pass is an **epoch-tagged submission**:
//! [`MoeEngine::submit`] stamps the next pass epoch, parks the inputs in
//! one of two pass slots, and rings the engine doorbell; the resident
//! actors pick the pass up, stamp the epoch into every one-sided transfer
//! (the symmetric heap's per-slot generation counters — no global reset),
//! and deposit their outputs back into the slot. [`PassHandle::wait`]
//! collects the [`ForwardResult`].
//!
//! Submission is pipelined: with two pass slots, `submit` of pass N+1
//! returns while pass N is still in flight (and `submit` of pass N+2
//! first drains pass N into a parking buffer), so a serving batcher can
//! pack the next batch while the current one runs. The actors execute
//! passes in epoch order; the slots double-buffer inputs/outputs, not
//! compute. Epoch assignment is a short critical section: a submitter
//! that must wait for its slot to drain waits on the *slot's* condvar
//! with the epoch lock released, so concurrent submitters (the serving
//! batcher plus direct embedders) interleave instead of serializing
//! behind one blocked `submit`.
//!
//! Passes are **variable-shape**: [`MoeEngine::submit_pass`] takes a
//! [`PassInput`] whose per-rank row counts `s_r` may be anywhere in
//! `0..=s_rank` — a partially-filled pass computes and ships only the
//! rows that exist (no padded-row compute or transfer; the dispatch
//! plan, announcement tables and task row counts all carry actual
//! counts). The fixed-shape [`MoeEngine::submit`] is the `s_r == s_rank`
//! special case and reports `PassMetrics::batch_fill == 1.0`.
//!
//! Shutdown is explicit ([`MoeEngine::shutdown`]) or automatic on drop:
//! the doorbell broadcasts the stop, every rank actor finishes any
//! already-submitted pass, parks its processors, and joins — no leaked
//! threads, verified by the engine lifecycle tests.

use std::collections::HashMap;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::atomic::Ordering;
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::thread::JoinHandle;
use std::time::Duration;

use anyhow::{anyhow, bail, ensure, Result};

use crate::config::Config;
use crate::expert::ModelParams;
use crate::fabric::SymmetricHeap;
use crate::fault;
use crate::layout::LayoutDims;
use crate::placement::{plan_replication, Placement};
use crate::registry::{DeltaSet, ModelHandle, ModelId};
use crate::runtime::ComputeBackend;
use crate::train::GradStore;
use crate::transport::NodeFabric;

use super::metrics::{EngineMetrics, PassMetrics};
use super::rank::{EngineShared, RankActor, RankOutput, TaskGraphMode, STASH_CAP};

/// Result of one distributed forward pass.
pub struct ForwardResult {
    /// Per-rank output matrices (s_r, H), row-major — the same per-rank
    /// row counts the pass was submitted with (`s_rank` rows everywhere
    /// on the fixed-shape path).
    pub outputs: Vec<Vec<f32>>,
    pub metrics: PassMetrics,
    /// Parameter-gradient partials merged across ranks: `Some` for a
    /// backward pass, `None` for forwards.
    pub grads: Option<GradStore>,
}

/// Result of one distributed **backward** pass (training): see
/// [`MoeEngine::backward`].
pub struct BackwardResult {
    /// Per-rank input gradients dL/dX, same shapes as the forward's
    /// inputs (including the gate's contribution).
    pub input_grads: Vec<Vec<f32>>,
    /// Parameter gradients of this micro-batch, merged across ranks in a
    /// fixed order — bitwise deterministic at any processor count.
    pub grads: GradStore,
    pub metrics: PassMetrics,
}

/// What a submitted pass computes. Backward passes ride the same slots,
/// epochs, doorbells, retry and poison machinery as forwards; the rank
/// actors dispatch on this tag.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum PassKind {
    Forward,
    Backward { fwd_epoch: u64 },
}

/// Variable-shape input for one engine pass: `per_rank[r]` is rank r's
/// `(s_r, H)` row-major token matrix with `s_r ≤ s_rank` (zero rows is
/// legal — such a rank contributes no tokens but still serves its
/// resident experts for its peers' dispatch). The engine validates the
/// shape at `submit_pass`; row counts are carried implicitly by the
/// buffer lengths, so a serving batcher packs exactly the rows it has
/// and never pads.
#[derive(Clone, Debug, Default)]
pub struct PassInput {
    /// Per-rank token matrices, `per_rank[r]` of length `s_r * H`.
    pub per_rank: Vec<Vec<f32>>,
    /// Which resident model the pass serves (0 = the anchor model the
    /// engine was started with; ids ≥ 1 are registry models — see
    /// [`MoeEngine::register_model`]). A pass never mixes models.
    pub model: ModelId,
}

impl PassInput {
    pub fn new(per_rank: Vec<Vec<f32>>) -> Self {
        Self { per_rank, model: 0 }
    }

    /// A pass routed to resident model `model` (0 = anchor). Non-anchor
    /// models must be registered and the engine must run in `Fused`
    /// task-graph mode — validated at submit.
    pub fn for_model(per_rank: Vec<Vec<f32>>, model: ModelId) -> Self {
        Self { per_rank, model }
    }

    /// Per-rank row counts at embedding width `h`.
    pub fn rows(&self, h: usize) -> Vec<usize> {
        self.per_rank.iter().map(|a| a.len() / h).collect()
    }

    /// Total token rows across ranks at embedding width `h`.
    pub fn total_rows(&self, h: usize) -> usize {
        self.per_rank.iter().map(|a| a.len() / h).sum()
    }
}

impl From<&[Vec<f32>]> for PassInput {
    fn from(inputs: &[Vec<f32>]) -> Self {
        Self { per_rank: inputs.to_vec(), model: 0 }
    }
}

/// How many passes may be in flight (submitted, not yet collected into
/// the parking buffer) at once. Two slots give submit/collect pipelining;
/// the actors themselves execute passes serially in epoch order.
const PASS_SLOTS: usize = 2;

struct PassSlot {
    state: Mutex<SlotState>,
    cv: Condvar,
}

struct SlotState {
    /// Epoch currently occupying the slot; 0 = free.
    epoch: u64,
    /// Forward or backward — what the rank actors should run for this
    /// epoch (backwards carry the stashed forward epoch to differentiate
    /// against).
    kind: PassKind,
    /// Resident model the occupying pass serves (0 = anchor; backwards
    /// are always anchor passes).
    model: ModelId,
    /// Epoch of the last pass freed (collected or parked) from this
    /// slot; 0 until the slot's first occupant completes. Together with
    /// `epoch == 0` this is the install turnstile: the submitter of
    /// epoch E may install only once its predecessor `E - PASS_SLOTS`
    /// has been freed, which keeps same-slot installs in epoch order
    /// even with many concurrent submitters.
    freed: u64,
    /// What the rank actors run on. Under a degraded placement these are
    /// the *repacked* per-rank matrices: a failed rank's rows are moved
    /// onto surviving ranks' spare capacity, so the corpse runs a
    /// zero-row pass and performs no transfer at all.
    inputs: Option<Arc<Vec<Vec<f32>>>>,
    /// The caller's original-shape inputs, retained so a poisoned pass
    /// can be resubmitted (and repacked afresh against whatever
    /// placement is live at retry time). Same `Arc` as `inputs` when no
    /// repack happened.
    orig: Option<Arc<Vec<Vec<f32>>>>,
    /// Repack moves `(failed rank, [(survivor, rows moved)..])` in the
    /// order rows were taken — `assemble` inverts them so the caller
    /// gets outputs in the shape it submitted.
    moves: Vec<(usize, Vec<(usize, usize)>)>,
    /// The pass ran under a degraded (post-`fail_rank`) placement.
    degraded: bool,
    /// Experts with no serving location under the pass's placement.
    experts_unavailable: usize,
    outputs: Vec<Option<Result<RankOutput>>>,
    deposited: usize,
    /// Placement version the occupying pass was submitted under —
    /// `rebalance` fences on drained slots, so this is also the version
    /// the pass *ran* under. Stamped into `PassMetrics`.
    placement_version: u64,
}

/// A completed pass displaced from its slot, awaiting its `wait()`:
/// the result plus — for a failed pass — the original-shape inputs the
/// retry loop resubmits.
struct Parked {
    result: Result<ForwardResult>,
    /// Original-shape inputs + pass kind + model, retained so a poisoned
    /// pass can be resubmitted as the same kind for the same model (a
    /// backward retries as a backward against the same stashed forward
    /// epoch; a model-B retry never perturbs model A).
    retry: Option<(Arc<Vec<Vec<f32>>>, PassKind, ModelId)>,
}

struct Submission {
    /// Highest epoch submitted so far.
    latest: u64,
    shutdown: bool,
}

/// State shared between the engine handle, its rank actor threads, and
/// any outstanding [`PassHandle`]s (which keep it alive past engine drop).
struct EngineInner {
    ranks: usize,
    /// Per-rank row capacity, for `PassMetrics::batch_fill` accounting.
    s_rank: usize,
    /// Wire element format, stamped into every pass's metrics (the byte
    /// counters are measured at this width).
    wire: crate::config::WirePrecision,
    /// The rank actors' shared state. Held here (not only on
    /// `MoeEngine`) so an outstanding [`PassHandle`] can retry a
    /// poisoned pass — resubmission and the degraded-placement swap both
    /// live behind the handle's `wait()`.
    shared: Arc<EngineShared>,
    /// Next epoch to assign; guards submission order (and, held across a
    /// quiet fence, placement swaps).
    next_epoch: Mutex<u64>,
    doorbell: Mutex<Submission>,
    doorbell_cv: Condvar,
    slots: [PassSlot; PASS_SLOTS],
    /// Completed passes displaced from their slot by a later submit,
    /// keyed by epoch, awaiting their `wait()`.
    parked: Mutex<HashMap<u64, Parked>>,
    metrics: Mutex<EngineMetrics>,
}

impl EngineInner {
    fn slot_of(&self, epoch: u64) -> &PassSlot {
        &self.slots[(epoch % PASS_SLOTS as u64) as usize]
    }
}

/// The persistent distributed MoE engine. See the module docs for the
/// lifecycle; the one-line version:
///
/// ```text
/// start(cfg, params, backend, mode)      // actors launched ONCE
///   -> submit(inputs) -> PassHandle      //  × N, pipelined
///   -> handle.wait()  -> ForwardResult   //  × N
/// -> shutdown() / drop                   // actors joined
/// ```
pub struct MoeEngine {
    shared: Arc<EngineShared>,
    inner: Arc<EngineInner>,
    rank_threads: Vec<JoinHandle<()>>,
}

/// An in-flight (or completed, not-yet-collected) epoch-tagged pass.
/// `wait()` consumes the handle and returns the pass result; dropping an
/// unwaited handle discards the result once the pass completes.
pub struct PassHandle {
    inner: Arc<EngineInner>,
    epoch: u64,
    collected: bool,
}

impl MoeEngine {
    /// Validate the configuration, allocate the symmetric heap, and launch
    /// the resident rank actors — the single "kernel launch" of the
    /// engine's lifetime. Steady-state passes spawn zero threads.
    pub fn start(
        cfg: Config,
        params: Arc<ModelParams>,
        backend: Arc<dyn ComputeBackend>,
        mode: TaskGraphMode,
    ) -> Result<Self> {
        cfg.validate()?;
        // One-time weight preparation (packed panels / literal uploads):
        // after this, steady-state passes do zero per-pass weight work —
        // the backend's pack counter stays flat for the engine lifetime.
        backend.prepare(&params)?;
        let dims = LayoutDims::from_config(&cfg);
        // The heap IS the wire: cells, transfers and byte counters all
        // live at the configured element width.
        let heap =
            Arc::new(SymmetricHeap::with_wire(dims, cfg.system.ranks_per_node(), cfg.system.wire));
        // Wrap the heap in the node-aware transport: NVLink-class puts go
        // straight through; NIC-class puts are admitted against a bounded
        // per-destination receive window first (the multi-node model).
        let fabric = Arc::new(NodeFabric::new(heap, &cfg));
        let ranks = cfg.system.ranks;
        let s_rank = cfg.system.s_rank;
        let wire = cfg.system.wire;
        let shared = Arc::new(EngineShared::new(cfg, params, fabric, backend, mode));
        let inner = Arc::new(EngineInner {
            ranks,
            s_rank,
            wire,
            shared: shared.clone(),
            next_epoch: Mutex::new(1),
            doorbell: Mutex::new(Submission { latest: 0, shutdown: false }),
            doorbell_cv: Condvar::new(),
            slots: std::array::from_fn(|_| PassSlot {
                state: Mutex::new(SlotState {
                    epoch: 0,
                    kind: PassKind::Forward,
                    model: 0,
                    freed: 0,
                    inputs: None,
                    orig: None,
                    moves: Vec::new(),
                    degraded: false,
                    experts_unavailable: 0,
                    outputs: Vec::new(),
                    deposited: 0,
                    placement_version: 0,
                }),
                cv: Condvar::new(),
            }),
            parked: Mutex::new(HashMap::new()),
            metrics: Mutex::new(EngineMetrics { launches: 1, ..Default::default() }),
        });
        let rank_threads = (0..ranks)
            .map(|rank| {
                let shared = shared.clone();
                let inner = inner.clone();
                shared.threads_spawned.fetch_add(1, Ordering::Relaxed);
                std::thread::Builder::new()
                    .name(format!("flash-rank{rank}"))
                    .spawn(move || rank_main(shared, inner, rank))
                    .expect("spawn rank actor")
            })
            .collect();
        Ok(Self { shared, inner, rank_threads })
    }

    pub fn config(&self) -> &Config {
        &self.shared.cfg
    }

    /// Snapshot of the engine's live parameters (training swaps them at
    /// quiet points via [`update_params`](Self::update_params); in-flight
    /// passes keep their own `Arc` snapshot).
    pub fn params(&self) -> Arc<ModelParams> {
        self.shared.params()
    }

    pub fn mode(&self) -> TaskGraphMode {
        self.shared.mode
    }

    /// Bytes of the symmetric tensor L per rank (Table 3's Size(L)), at
    /// the configured wire element width — a 16-bit wire halves it.
    pub fn heap_bytes_per_rank(&self) -> f64 {
        self.shared.fabric.bytes_per_rank() as f64
    }

    /// Snapshot of the cumulative engine metrics. `launches` is 1 for the
    /// engine's lifetime; `threads_spawned` stops growing after `start`.
    pub fn metrics(&self) -> EngineMetrics {
        let mut m = self.inner.metrics.lock().unwrap().clone();
        m.threads_spawned = self.shared.threads_spawned.load(Ordering::Relaxed);
        if let Some(fp) = self.shared.fabric.fault_plan() {
            m.faults_injected = fp.faults_injected();
        }
        m
    }

    /// Snapshot of the live expert→location placement.
    pub fn placement(&self) -> Arc<Placement> {
        self.shared.placement()
    }

    /// Re-plan hot-expert replication from the EWMA load tracker and, if
    /// the plan changed, install the new placement. Returns whether a
    /// swap happened. No-op (`Ok(false)`) when the policy is disabled or
    /// no pass has been observed yet.
    ///
    /// **Epoch fence**: the placement may only change with no pass in
    /// flight — a pass snapshots the map once at its start, and a swap
    /// mid-pass would desynchronize ranks. `rebalance` holds the epoch
    /// lock (blocking new submits) and waits for every occupied pass
    /// slot to finish depositing before swapping, so it runs strictly
    /// *between* passes. Replica weight installs are modeled accounting
    /// (`EngineMetrics::{replica_installs, install_bytes}`): the
    /// in-process backend packed every expert at `start`, so a new
    /// binding needs no data movement here — but the placement swap is
    /// still the real synchronization point a hardware port would fence
    /// its weight copies on.
    pub fn rebalance(&self) -> Result<bool> {
        let policy = &self.shared.cfg.system.replication;
        if !policy.enabled() {
            return Ok(false);
        }
        // Hold the epoch lock for the whole swap: no new epoch can be
        // assigned while we fence and swap (`quiet_fence` returns the
        // held guard after every assigned epoch has fully deposited).
        let _turnstile = quiet_fence(&self.inner);
        // Anchor model first (the legacy placement/tracker fields), then
        // every registry model: replication decisions are per-model — a
        // hot expert in model A says nothing about model B — so each
        // model's EWMA tracker drives its own map.
        let mut swapped = {
            let current = self.shared.placement();
            let proposed = {
                let tracker = self.shared.tracker.lock().unwrap();
                plan_replication(policy, &tracker, &current)
            };
            if proposed.same_locations(&current) {
                false
            } else {
                self.book_replica_moves(&current, &proposed, &self.shared.params());
                self.shared.set_placement(Arc::new(proposed));
                true
            }
        };
        for id in self.shared.registry.resident_models() {
            if id == 0 {
                continue;
            }
            let Some(entry) = self.shared.registry.entry(id) else { continue };
            let current = entry.placement.lock().unwrap().clone();
            let proposed = {
                let tracker = entry.tracker.lock().unwrap();
                plan_replication(policy, &tracker, &current)
            };
            if proposed.same_locations(&current) {
                continue;
            }
            self.book_replica_moves(&current, &proposed, &entry.params);
            *entry.placement.lock().unwrap() = Arc::new(proposed);
            swapped = true;
        }
        Ok(swapped)
    }

    /// Book the weight movement of one placement swap: every
    /// (expert, rank) serving pair that is new in the proposed map is one
    /// expert-install onto that rank; every pair that vanished is a
    /// removal.
    fn book_replica_moves(&self, current: &Placement, proposed: &Placement, p: &ModelParams) {
        let (mut installs, mut removals, mut bytes) = (0u64, 0u64, 0u64);
        for ex in 0..proposed.num_experts() {
            let old = current.locations(ex);
            let new = proposed.locations(ex);
            for &(r, _) in new {
                if !old.iter().any(|&(or, _)| or == r) {
                    installs += 1;
                    bytes += p.experts[ex].size_bytes() as u64;
                }
            }
            for &(r, _) in old {
                if !new.iter().any(|&(nr, _)| nr == r) {
                    removals += 1;
                }
            }
        }
        let mut em = self.inner.metrics.lock().unwrap();
        em.replica_installs += installs;
        em.replica_removals += removals;
        em.install_bytes += bytes;
    }

    /// Register a full expert set as a new resident model, at the same
    /// epoch-fenced quiet point a `rebalance` swap uses (no pass in
    /// flight observes a half-registered model). The weights are
    /// content-fingerprinted first: a match against any resident model
    /// shares that model's packed-cache region (zero new packs — audit
    /// with the backend's pack counter); fresh weights are packed once
    /// into their own key region. The returned [`ModelHandle`] carries
    /// the assigned id, the fingerprint, and what residency actually
    /// cost. Requires `Fused` mode and a free slot
    /// (`SystemConfig::max_models`, knob `max_models`).
    pub fn register_model(&self, params: Arc<ModelParams>) -> Result<ModelHandle> {
        ensure!(
            self.shared.mode == TaskGraphMode::Fused,
            "multi-model residency requires Fused task-graph mode"
        );
        let fence = quiet_fence(&self.inner);
        let backend = self.shared.backend.clone();
        let pack_params = params.clone();
        let handle = self.shared.registry.register_base(&self.shared.cfg, params, |key_base| {
            backend.prepare_model(&pack_params, key_base)
        })?;
        self.inherit_failed_ranks(handle.id);
        self.inner.metrics.lock().unwrap().model_registrations += 1;
        drop(fence);
        Ok(handle)
    }

    /// Register a LoRA-style [`DeltaSet`] as a variant of resident model
    /// `base` (epoch-fenced, like [`register_model`](Self::register_model)):
    /// the variant shares the base's parameters and packed panels and
    /// stores only the low-rank tensors, which the rank actors apply in
    /// each FFN tile's epilogue — residency costs `DeltaSet::bytes()`,
    /// never a repack.
    pub fn register_delta(&self, base: ModelId, delta: Arc<DeltaSet>) -> Result<ModelHandle> {
        ensure!(
            self.shared.mode == TaskGraphMode::Fused,
            "multi-model residency requires Fused task-graph mode"
        );
        let fence = quiet_fence(&self.inner);
        let handle = self.shared.registry.register_delta(&self.shared.cfg, base, delta)?;
        self.inherit_failed_ranks(handle.id);
        self.inner.metrics.lock().unwrap().model_registrations += 1;
        drop(fence);
        Ok(handle)
    }

    /// A model registered after a permanent rank death must not route to
    /// the corpse: copy the anchor placement's failed-rank set into the
    /// fresh entry's map. Caller holds the quiet fence.
    fn inherit_failed_ranks(&self, model: ModelId) {
        let Some(entry) = self.shared.registry.entry(model) else { return };
        let anchor = self.shared.placement();
        if !anchor.degraded() {
            return;
        }
        let mut pl = entry.placement.lock().unwrap();
        let mut next = (**pl).clone();
        for r in 0..self.shared.cfg.system.ranks {
            if anchor.is_failed(r) {
                next.fail_rank(r);
            }
        }
        *pl = Arc::new(next);
    }

    /// Evict a resident model at the epoch-fenced quiet point, freeing
    /// its registry slot (its heap band simply goes quiet). The anchor
    /// (id 0) and any model that other residents depend on — a delta's
    /// base, or the pack-region owner of a deduped registration — refuse
    /// eviction.
    pub fn evict_model(&self, model: ModelId) -> Result<()> {
        let fence = quiet_fence(&self.inner);
        self.shared.registry.evict(model)?;
        self.inner.metrics.lock().unwrap().model_evictions += 1;
        drop(fence);
        Ok(())
    }

    /// Resident model ids, ascending (always starts with the anchor, 0).
    pub fn resident_models(&self) -> Vec<ModelId> {
        self.shared.registry.resident_models()
    }

    /// Total resident weight bytes across all models, counting every
    /// shared packed region once — the figure the multi-model bench
    /// compares against N dedicated engines.
    pub fn resident_bytes(&self) -> usize {
        self.shared.registry.resident_bytes()
    }

    /// Submit one fixed-shape, epoch-tagged forward pass: `inputs[r]` is
    /// rank r's full (S_r, H) token matrix. This is the legacy front door
    /// — a thin shim that validates every rank is exactly full (so
    /// `PassMetrics::batch_fill` reads 1.0) and delegates to
    /// [`submit_pass`](Self::submit_pass).
    pub fn submit(&self, inputs: &[Vec<f32>]) -> Result<PassHandle> {
        let cfg = &self.shared.cfg;
        let want = cfg.system.s_rank * cfg.model.h;
        for (r, a) in inputs.iter().enumerate() {
            anyhow::ensure!(
                a.len() == want,
                "rank {r}: input length {} != S_r*H = {want}",
                a.len()
            );
        }
        self.submit_pass(PassInput::from(inputs))
    }

    /// Submit one **variable-shape** epoch-tagged pass: rank r runs on
    /// `input.per_rank[r].len() / H` rows, anywhere in `0..=s_rank`.
    /// Inputs are copied into the pass slot so the caller may reuse its
    /// buffers immediately. Returns a [`PassHandle`]; the pass runs on
    /// the resident actors while the caller continues (e.g. packing the
    /// next batch). With this epoch's slot still occupied by the pass
    /// from `PASS_SLOTS` submits ago, `submit_pass` waits for that pass
    /// to finish and parks its result for the eventual `wait()` — that
    /// wait happens on the slot's condvar with the epoch lock released,
    /// so one blocked submitter never serializes the others.
    pub fn submit_pass(&self, input: PassInput) -> Result<PassHandle> {
        let epoch = submit_inner(&self.inner, input.per_rank, PassKind::Forward, input.model)?;
        Ok(PassHandle { inner: self.inner.clone(), epoch, collected: false })
    }

    /// Convenience: submit one pass and wait for it (no pipelining).
    pub fn forward(&self, inputs: &[Vec<f32>]) -> Result<ForwardResult> {
        self.submit(inputs)?.wait()
    }

    /// Run the backward pass for the stashed forward `fwd_epoch`:
    /// `grad_out[r]` is rank r's dL/dY, the same (rows, H) shape the
    /// forward returned. The gradients travel the *reverse* wire — output
    /// grads scatter to the expert owners at the configured
    /// `WirePrecision`, `Dgrad`/`Wgrad` tile tasks run on the same
    /// resident work-stealing processors, input grads gather back over
    /// the combine cells — and the same epoch/retry/poison machinery
    /// covers them, so a transient fault retries bitwise-identically.
    ///
    /// Requires the forward to have run with activation stashing on
    /// (`cfg.system.train` — see [`crate::train`]) in `Fused` mode, and
    /// its stash to still be resident (the last `STASH_CAP` stashed
    /// epochs per rank; older ones are evicted).
    pub fn backward(&self, fwd_epoch: u64, grad_out: &[Vec<f32>]) -> Result<BackwardResult> {
        let cfg = &self.shared.cfg;
        ensure!(
            cfg.system.train.stash(),
            "backward requires activation stashing: set train=on (or stash_activations=on)"
        );
        ensure!(
            self.shared.mode == TaskGraphMode::Fused,
            "backward is only supported in Fused task-graph mode"
        );
        ensure!(
            grad_out.len() == cfg.system.ranks,
            "need {} rank grad buffers, got {}",
            cfg.system.ranks,
            grad_out.len()
        );
        for (r, g) in grad_out.iter().enumerate() {
            let stash = self.shared.stash_for(r, fwd_epoch).ok_or_else(|| {
                anyhow!(
                    "rank {r} has no activation stash for forward epoch {fwd_epoch} \
                     (evicted after {STASH_CAP} newer stashed passes, or the forward \
                     predates train=on)"
                )
            })?;
            ensure!(
                g.len() == stash.s_rows * cfg.model.h,
                "rank {r}: grad_out length {} != rows*H = {} stashed for epoch {fwd_epoch}",
                g.len(),
                stash.s_rows * cfg.model.h
            );
            ensure!(
                stash.placement_version == self.shared.placement().version(),
                "placement changed since forward epoch {fwd_epoch} \
                 (stash v{}, live v{}): the reverse routes no longer match",
                stash.placement_version,
                self.shared.placement().version()
            );
        }
        let epoch =
            submit_inner(&self.inner, grad_out.to_vec(), PassKind::Backward { fwd_epoch }, 0)?;
        let fr = collect_retrying(&self.inner, epoch)?;
        let grads = fr.grads.expect("backward pass merges grads");
        Ok(BackwardResult { input_grads: fr.outputs, grads, metrics: fr.metrics })
    }

    /// Install updated parameters at an epoch-fenced quiet point (no pass
    /// in flight): the backend re-prepares its packed panels, then the
    /// shared snapshot is swapped so the next pass runs on the new
    /// weights. In-flight stashes keep their own parameter snapshots, so
    /// a backward of an *older* forward still differentiates against the
    /// weights that forward actually ran on.
    pub fn update_params(&self, params: ModelParams) -> Result<()> {
        ensure!(
            self.shared.mode == TaskGraphMode::Fused,
            "update_params is only supported in Fused task-graph mode"
        );
        let m = &self.shared.cfg.model;
        ensure!(
            params.h == m.h && params.d == m.d && params.experts.len() == m.e,
            "parameter shape (h={}, d={}, e={}) does not match the engine config \
             (h={}, d={}, e={})",
            params.h,
            params.d,
            params.experts.len(),
            m.h,
            m.d,
            m.e
        );
        let params = Arc::new(params);
        let fence = quiet_fence(&self.inner);
        // `refresh` rewrites the anchor's packed region (key base 0). A
        // deduped registration or delta variant sharing that region would
        // silently start serving the *new* panels against its *old*
        // parameter snapshot — refuse until those models are evicted.
        let dependents: Vec<ModelId> = self
            .shared
            .registry
            .resident_models()
            .into_iter()
            .filter(|&id| {
                id != 0
                    && self.shared.registry.entry(id).is_some_and(|e| e.key_base == 0)
            })
            .collect();
        ensure!(
            dependents.is_empty(),
            "update_params would invalidate resident models {dependents:?} that share \
             the anchor's packed weights (dedup or delta variants): evict them first"
        );
        self.shared.backend.refresh(&params)?;
        self.shared.set_params(params);
        drop(fence);
        Ok(())
    }

    /// Stop the engine: broadcast shutdown, let the actors finish any
    /// already-submitted passes, park + join every resident thread.
    /// Also runs on drop; calling it explicitly just surfaces the intent.
    pub fn shutdown(mut self) {
        self.shutdown_and_join();
    }

    fn shutdown_and_join(&mut self) {
        {
            let mut bell = self.inner.doorbell.lock().unwrap();
            bell.shutdown = true;
            self.inner.doorbell_cv.notify_all();
        }
        for h in self.rank_threads.drain(..) {
            let _ = h.join();
        }
    }
}

impl Drop for MoeEngine {
    fn drop(&mut self) {
        self.shutdown_and_join();
    }
}

impl PassHandle {
    /// The engine epoch of this pass (1-based submission order).
    pub fn epoch(&self) -> u64 {
        self.epoch
    }

    /// Block until the pass completes and return its result. Outstanding
    /// handles stay valid across engine shutdown/drop for passes that
    /// were already submitted (the actors drain them before exiting).
    ///
    /// This is also where fault recovery lives: a pass that failed for a
    /// *retryable* reason (injected transient fault, dead-rank endpoint,
    /// incast overload, watchdog abandonment) is transparently
    /// resubmitted — up to `SystemConfig::retry_limit` times, with
    /// exponential backoff — from the caller's original-shape inputs. A
    /// permanently dead rank additionally triggers the epoch-fenced
    /// degraded-placement swap before the retry, so the resubmission
    /// routes around the corpse via replicas.
    pub fn wait(mut self) -> Result<ForwardResult> {
        self.collected = true;
        collect_retrying(&self.inner, self.epoch)
    }
}

impl Drop for PassHandle {
    fn drop(&mut self) {
        if !self.collected {
            // Free the pass slot so later submits don't stall on an
            // abandoned pass; the result is discarded (no retry — only
            // an explicit `wait()` spends retry budget).
            let _ = collect2(&self.inner, self.epoch);
        }
    }
}

/// Move a failed rank's rows onto surviving ranks' spare capacity so the
/// corpse runs a zero-row pass. Returns the moves needed to invert the
/// repack (`unpack_rows`), or an error when the surviving ranks cannot
/// absorb the displaced rows — in which case `per_rank` must be discarded
/// (it may be half-repacked) but no epoch has been consumed.
fn repack_inputs(
    per_rank: &mut Vec<Vec<f32>>,
    placement: &Placement,
    h: usize,
    s_rank: usize,
) -> Result<Vec<(usize, Vec<(usize, usize)>)>> {
    let mut moves = Vec::new();
    for dead in 0..per_rank.len() {
        if !placement.is_failed(dead) || per_rank[dead].is_empty() {
            continue;
        }
        let rows = per_rank[dead].len() / h;
        let data = std::mem::take(&mut per_rank[dead]);
        let mut segs = Vec::new();
        let mut off = 0usize;
        for s in 0..per_rank.len() {
            if off == rows {
                break;
            }
            if placement.is_failed(s) {
                continue;
            }
            let spare = s_rank - per_rank[s].len() / h;
            if spare == 0 {
                continue;
            }
            let take = spare.min(rows - off);
            per_rank[s].extend_from_slice(&data[off * h..(off + take) * h]);
            segs.push((s, take));
            off += take;
        }
        ensure!(
            off == rows,
            "degraded capacity: {} rows from failed rank {dead} exceed surviving spare capacity",
            rows - off
        );
        moves.push((dead, segs));
    }
    Ok(moves)
}

/// Invert `repack_inputs` on the pass outputs: peel each survivor's
/// borrowed rows back off (they were appended, so they sit at the tail,
/// with the *last* repacked corpse's rows outermost) and reconstitute the
/// failed ranks' output matrices in submission shape.
fn unpack_rows(outputs: &mut [Vec<f32>], moves: &[(usize, Vec<(usize, usize)>)], h: usize) {
    for (dead, segs) in moves.iter().rev() {
        let mut restored: Vec<Vec<f32>> = Vec::with_capacity(segs.len());
        for &(s, take) in segs.iter().rev() {
            let keep = outputs[s].len() - take * h;
            restored.push(outputs[s].split_off(keep));
        }
        restored.reverse();
        outputs[*dead] = restored.concat();
    }
}

/// Validate, epoch-stamp, and install one pass. Shared by the public
/// submit path and the retry loop (which runs from a `PassHandle`, after
/// the engine handle may already be gone). Returns the assigned epoch.
fn submit_inner(
    inner: &Arc<EngineInner>,
    mut per_rank: Vec<Vec<f32>>,
    kind: PassKind,
    model: ModelId,
) -> Result<u64> {
    let cfg = &inner.shared.cfg;
    let h = cfg.model.h;
    if model != 0 {
        ensure!(
            inner.shared.mode == TaskGraphMode::Fused,
            "model {model}: non-anchor models serve in Fused task-graph mode only"
        );
        ensure!(
            inner.shared.registry.is_resident(model),
            "model {model} is not resident (register it first)"
        );
    }
    ensure!(
        per_rank.len() == cfg.system.ranks,
        "need {} rank inputs, got {}",
        cfg.system.ranks,
        per_rank.len()
    );
    for (r, a) in per_rank.iter().enumerate() {
        ensure!(
            a.len() % h == 0,
            "rank {r}: input length {} is not a multiple of H = {h}",
            a.len()
        );
        ensure!(
            a.len() / h <= cfg.system.s_rank,
            "rank {r}: {} rows exceed s_rank = {}",
            a.len() / h,
            cfg.system.s_rank
        );
    }

    // Epoch assignment happens under the doorbell lock, with the ring in
    // the same critical section: either we observe shutdown and consume
    // no epoch, or the rank actors are guaranteed to see (and drain) our
    // epoch before they exit — the mutex totally orders us against the
    // shutdown broadcast. All validation precedes assignment (an assigned
    // epoch MUST reach its slot, or every later pass in the same slot
    // would wedge); the install itself happens after the ring, which
    // rank_main explicitly tolerates (it waits on the slot for `next`).
    let (epoch, orig, moves, degraded, experts_unavailable, placement_version) = {
        let mut bell = inner.doorbell.lock().unwrap();
        if bell.shutdown {
            bail!("engine is shut down");
        }
        let mut next = inner.next_epoch.lock().unwrap();
        // Snapshot the *pass model's* placement inside the epoch critical
        // section so the repack and the pass run against the same map
        // (`rebalance`, the degrade swap, and model load/evict all hold
        // `next_epoch` across their fence). Re-check residency under the
        // lock: an evict may have raced the pre-lock validation.
        let placement = if model == 0 {
            inner.shared.placement()
        } else {
            inner
                .shared
                .registry
                .entry(model)
                .ok_or_else(|| anyhow!("model {model} was evicted before the pass started"))?
                .placement
                .lock()
                .unwrap()
                .clone()
        };
        let (orig, moves, degraded, experts_unavailable) = if placement.degraded() {
            // A backward's grad rows must land on the exact ranks that
            // stashed the forward — the row repack that keeps forwards
            // serving under a degraded placement would break that
            // correspondence, so refuse before consuming an epoch.
            ensure!(
                kind == PassKind::Forward,
                "backward cannot run under a degraded placement: re-run the forward \
                 against the degraded map first"
            );
            let orig = Arc::new(per_rank.clone());
            let moves = repack_inputs(&mut per_rank, &placement, h, cfg.system.s_rank)?;
            (orig, moves, true, placement.unavailable_experts().len())
        } else {
            (Arc::new(Vec::new()), Vec::new(), false, 0)
        };
        let epoch = *next;
        *next += 1;
        drop(next);
        bell.latest = bell.latest.max(epoch);
        inner.doorbell_cv.notify_all();
        (epoch, orig, moves, degraded, experts_unavailable, placement.version())
    };
    let inputs = Arc::new(per_rank);
    // Under a non-degraded placement the retry ticket IS the submitted
    // buffer — no second copy.
    let orig = if degraded { orig } else { inputs.clone() };

    let slot = inner.slot_of(epoch);
    let prev = epoch.saturating_sub(PASS_SLOTS as u64);
    {
        let mut st = slot.state.lock().unwrap();
        loop {
            if st.epoch == 0 && st.freed == prev {
                // Our predecessor in this slot was freed (collected
                // by a wait() or parked by us/another submitter):
                // our turn to install.
                break;
            }
            if st.epoch == prev && st.deposited >= inner.ranks {
                // Predecessor complete but uncollected: drain it into
                // the parking buffer for its eventual `wait()`.
                let parked = assemble(inner, &mut st);
                inner.parked.lock().unwrap().insert(prev, parked);
                break;
            }
            // Predecessor still in flight (or not even installed yet,
            // its submitter racing us): wait on the slot, not the
            // epoch lock.
            st = slot.cv.wait(st).unwrap();
        }
        st.epoch = epoch;
        st.kind = kind;
        st.model = model;
        st.inputs = Some(inputs);
        st.orig = Some(orig);
        st.moves = moves;
        st.degraded = degraded;
        st.experts_unavailable = experts_unavailable;
        st.outputs = (0..inner.ranks).map(|_| None).collect();
        st.deposited = 0;
        st.placement_version = placement_version;
        // wake rank actors (and same-slot submitters) waiting for the
        // install
        slot.cv.notify_all();
    }
    Ok(epoch)
}

/// Collect the result for `epoch`: from the parking buffer if a later
/// submit already drained it, otherwise from its slot (blocking until the
/// actors deposit all rank outputs). Alongside the result, returns the
/// retry ticket — the pass's original-shape inputs — when the pass failed.
fn collect2(
    inner: &Arc<EngineInner>,
    epoch: u64,
) -> (Result<ForwardResult>, Option<(Arc<Vec<Vec<f32>>>, PassKind, ModelId)>) {
    let slot = inner.slot_of(epoch);
    let mut st = slot.state.lock().unwrap();
    if st.epoch == epoch {
        // A concurrent submit draining this slot into the parking buffer
        // may beat us to it — re-check ownership after every wake.
        while st.epoch == epoch && st.deposited < inner.ranks {
            st = slot.cv.wait(st).unwrap();
        }
        if st.epoch == epoch {
            let p = assemble(inner, &mut st);
            return (p.result, p.retry);
        }
    }
    drop(st);
    // Not in its slot: either parked by a later submit, or already taken.
    // (`parked` is only mutated under the slot lock, so this is race-free.)
    match inner.parked.lock().unwrap().remove(&epoch) {
        Some(p) => (p.result, p.retry),
        None => (
            Err(anyhow!("pass {epoch} was never submitted or already collected")),
            None,
        ),
    }
}

/// Assemble a completed slot into a parked result, free the slot, and
/// fold the pass into the cumulative engine metrics. Caller holds the
/// slot lock with all rank outputs deposited.
fn assemble(inner: &Arc<EngineInner>, st: &mut SlotState) -> Parked {
    let epoch = st.epoch;
    let kind = st.kind;
    let model = st.model;
    let rank_outputs: Vec<Result<RankOutput>> =
        st.outputs.iter_mut().map(|o| o.take().expect("deposited output")).collect();
    let orig = st.orig.take();
    let moves = std::mem::take(&mut st.moves);
    let degraded = st.degraded;
    let experts_unavailable = st.experts_unavailable;
    st.epoch = 0;
    st.kind = PassKind::Forward;
    st.model = 0;
    st.freed = epoch;
    st.inputs = None;
    st.degraded = false;
    st.experts_unavailable = 0;
    st.deposited = 0;
    let placement_version = st.placement_version;
    // wake a submit that may be waiting to reuse this slot
    inner.slot_of(epoch).cv.notify_all();

    let mut outputs = Vec::with_capacity(rank_outputs.len());
    let mut metrics = PassMetrics {
        epoch,
        rows_capacity: inner.ranks * inner.s_rank,
        wire: inner.wire,
        placement_version,
        experts_unavailable,
        backward: kind != PassKind::Forward,
        model,
        ..Default::default()
    };
    let mut grads: Option<GradStore> = None;
    let m = &inner.shared.cfg.model;
    for (rank, ro) in rank_outputs.into_iter().enumerate() {
        let ro = match ro {
            Ok(ro) => ro,
            Err(e) => {
                return Parked {
                    result: Err(e.context(format!("pass {epoch}, rank {rank}"))),
                    retry: orig.map(|o| (o, kind, model)),
                }
            }
        };
        metrics.wall_secs = metrics.wall_secs.max(ro.metrics.wall_secs);
        metrics.rows_submitted += ro.metrics.rows_in;
        metrics.ranks.push(ro.metrics);
        // Merge per-rank gradient partials ranks-ascending — a fixed fold
        // order, so the merged grads are bitwise deterministic.
        if let Some(rg) = ro.grads {
            let g = grads.get_or_insert_with(|| GradStore::zeros(m.h, m.d, m.e));
            for (gv, &sv) in g.wg.iter_mut().zip(&rg.wg) {
                *gv += sv;
            }
            for (ge, eg) in rg.experts {
                g.experts[ge].add_assign(&eg);
            }
        }
        outputs.push(ro.out);
    }
    unpack_rows(&mut outputs, &moves, inner.shared.cfg.model.h);
    {
        let mut em = inner.metrics.lock().unwrap();
        em.wall_secs += metrics.wall_secs;
        em.busy_secs += metrics.ranks.iter().map(|r| r.busy_secs).sum::<f64>();
        if metrics.backward {
            em.backward_passes += 1;
            em.reverse_bytes += metrics.total_bytes();
        } else {
            em.passes += 1;
            em.forward_bytes += metrics.total_bytes();
        }
        if degraded {
            em.degraded_passes += 1;
        }
    }
    Parked { result: Ok(ForwardResult { outputs, metrics, grads }), retry: None }
}

/// Wait until every assigned epoch has fully deposited, holding the epoch
/// lock so no new epoch can be assigned meanwhile. Returns the held guard:
/// the caller performs its placement swap (or other between-passes
/// mutation) and then releases it. Per slot, the last assigned epoch must
/// be freed, or occupying the slot with all rank outputs in. (Checking
/// only "slot drained" would miss an epoch whose submitter is still
/// waiting to install it; that pass would then run concurrently with the
/// swap and its ranks could snapshot different placement versions.)
fn quiet_fence(inner: &Arc<EngineInner>) -> MutexGuard<'_, u64> {
    let turnstile = inner.next_epoch.lock().unwrap();
    let latest = *turnstile - 1;
    for (i, slot) in inner.slots.iter().enumerate() {
        if latest == 0 {
            break; // nothing ever submitted
        }
        // greatest assigned epoch that maps to slot i (epochs are
        // 1-based and strike slots round-robin by `epoch % SLOTS`)
        let lag = (latest as usize + PASS_SLOTS - i) % PASS_SLOTS;
        let last = latest - lag as u64;
        if last == 0 {
            continue;
        }
        let mut st = slot.state.lock().unwrap();
        while !(st.freed == last || (st.epoch == last && st.deposited >= inner.ranks)) {
            st = slot.cv.wait(st).unwrap();
        }
    }
    turnstile
}

/// Epoch-fenced degraded-placement swap: evict a permanently dead rank's
/// expert locations (replicas on surviving ranks keep those experts
/// servable; un-replicated experts become explicitly unavailable). Runs
/// strictly between passes, like `rebalance`.
fn degrade_placement(inner: &Arc<EngineInner>, rank: usize) {
    let fence = quiet_fence(inner);
    // A dead rank is dead for every resident model, so fail it in the
    // anchor map and in each registry model's map. Another waiter may
    // have degraded the same rank while we fenced — each map checks
    // independently (a model registered mid-degrade inherits the failed
    // set at registration instead).
    if !inner.shared.placement().is_failed(rank) {
        let mut next = (*inner.shared.placement()).clone();
        next.fail_rank(rank);
        inner.shared.set_placement(Arc::new(next));
    }
    for id in inner.shared.registry.resident_models() {
        if id == 0 {
            continue;
        }
        let Some(entry) = inner.shared.registry.entry(id) else { continue };
        let mut pl = entry.placement.lock().unwrap();
        if pl.is_failed(rank) {
            continue;
        }
        let mut next = (**pl).clone();
        next.fail_rank(rank);
        *pl = Arc::new(next);
    }
    drop(fence);
}

/// `collect2` plus the pass-level retry loop: classify the failure,
/// degrade the placement when the fault plan says the rank is permanently
/// dead, back off, and resubmit from the original-shape inputs — up to
/// `SystemConfig::retry_limit` times. A transient fault therefore yields
/// the same bitwise output as a fault-free run, one retry later.
fn collect_retrying(inner: &Arc<EngineInner>, epoch: u64) -> Result<ForwardResult> {
    let limit = inner.shared.cfg.system.retry_limit;
    let mut tries = 0u32;
    let mut cur_epoch = epoch;
    let (mut result, mut retry) = collect2(inner, epoch);
    loop {
        let err = match result {
            Ok(mut fr) => {
                fr.metrics.retries = tries;
                if tries > 0 {
                    inner.metrics.lock().unwrap().retries += tries as u64;
                }
                return Ok(fr);
            }
            Err(e) => e,
        };
        let msg = format!("{err:#}");
        // A permanent rank death degrades the placement regardless of
        // retry budget — later passes must route around the corpse even
        // if *this* pass is reported failed.
        let dead = inner
            .shared
            .fabric
            .fault_plan()
            .and_then(|fp| fp.dead_rank(cur_epoch as u32));
        if let Some(r) = dead {
            if !inner.shared.placement().is_failed(r) {
                degrade_placement(inner, r);
            }
        }
        let retryable = dead.is_some()
            || fault::is_transient(&msg)
            || fault::is_dead_rank(&msg)
            || msg.contains("incast")
            || msg.contains("abandoning pass gen");
        let Some((inputs, kind, model)) = retry.take() else { return Err(err) };
        if !retryable || (tries as usize) >= limit {
            return Err(err);
        }
        if inner.doorbell.lock().unwrap().shutdown {
            return Err(err.context("engine shut down before the pass could be retried"));
        }
        std::thread::sleep(Duration::from_millis(1u64 << tries.min(6)));
        tries += 1;
        match submit_inner(inner, inputs.as_ref().clone(), kind, model) {
            Ok(e2) => {
                cur_epoch = e2;
                let (r2, t2) = collect2(inner, e2);
                result = r2;
                retry = t2;
            }
            Err(e) => return Err(e.context(format!("resubmission after: {msg}"))),
        }
    }
}

/// Fold one fully-deposited pass into the shared EWMA load tracker:
/// per-expert *offered* load (un-clamped gate demand, summed over ranks)
/// plus per-rank busy seconds. Called by the last depositing rank under
/// the slot lock; skipped entirely when replication is off.
fn observe_pass(shared: &EngineShared, st: &SlotState) {
    if !shared.cfg.system.replication.enabled() {
        return;
    }
    // Backward passes carry no offered-load signal (the routing already
    // happened at the forward); folding their zeros in would decay the
    // EWMA and skew replication decisions.
    if st.kind != PassKind::Forward {
        return;
    }
    let e = shared.cfg.model.e;
    let mut offered = vec![0u64; e];
    let mut busy = vec![0.0f64; shared.cfg.system.ranks];
    for (rank, out) in st.outputs.iter().enumerate() {
        if let Some(Ok(ro)) = out {
            for (i, &v) in ro.metrics.expert_offered.iter().take(e).enumerate() {
                offered[i] += v;
            }
            busy[rank] = ro.metrics.busy_secs;
        }
    }
    // Each model keeps its own EWMA: a hot expert in one model must not
    // trigger replication (or mask a cold expert) in another.
    if st.model == 0 {
        shared.tracker.lock().unwrap().observe(&offered, &busy);
    } else if let Some(entry) = shared.registry.entry(st.model) {
        entry.tracker.lock().unwrap().observe(&offered, &busy);
    }
}

/// A rank actor's main thread: spawn the resident worker group once, then
/// serve epoch after epoch from the engine doorbell until shutdown.
fn rank_main(shared: Arc<EngineShared>, inner: Arc<EngineInner>, rank: usize) {
    let actor = RankActor::spawn(shared, rank);
    let mut next = 1u64;
    loop {
        let quit = {
            let mut bell = inner.doorbell.lock().unwrap();
            loop {
                if bell.latest >= next {
                    break false; // drain submitted passes even under shutdown
                }
                if bell.shutdown {
                    break true;
                }
                bell = inner.doorbell_cv.wait(bell).unwrap();
            }
        };
        if quit {
            break;
        }
        let slot = inner.slot_of(next);
        let (inputs, kind, model) = {
            // The doorbell only guarantees *some* epoch >= `next` was
            // submitted; with concurrent submitters, epoch `next + 1`
            // (the other slot) may ring before `next` is installed here.
            // An assigned epoch always reaches its slot (validation
            // precedes assignment), so this wait is bounded by that
            // submitter's install.
            let mut st = slot.state.lock().unwrap();
            while st.epoch != next {
                st = slot.cv.wait(st).unwrap();
            }
            (st.inputs.as_ref().expect("submitted inputs").clone(), st.kind, st.model)
        };
        // A subscriber watchdog panic must not wedge `wait()`ers: convert
        // it into a deposited error instead of a dead slot. Before serving
        // another epoch, re-synchronize the rank's workers (the unwound
        // pass may have left them mid-drain on its queue).
        let result = match catch_unwind(AssertUnwindSafe(|| match kind {
            PassKind::Forward => actor.run_pass(next, &inputs[rank], model),
            PassKind::Backward { fwd_epoch } => {
                actor.run_backward_pass(next, fwd_epoch, &inputs[rank])
            }
        })) {
            Ok(r) => r,
            Err(p) => {
                let msg = p
                    .downcast_ref::<String>()
                    .cloned()
                    .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                    .unwrap_or_else(|| "unknown panic".to_string());
                actor.quiesce(next);
                Err(anyhow!("rank {rank} panicked in pass {next}: {msg}"))
            }
        };
        {
            let mut st = slot.state.lock().unwrap();
            st.outputs[rank] = Some(result);
            st.deposited += 1;
            if st.deposited == inner.ranks {
                // Last depositor feeds the replication tracker with the
                // pass's offered-load signal, before waiters wake — so a
                // `wait()` → `rebalance()` sequence always sees this
                // pass's observation.
                observe_pass(&shared, &st);
                slot.cv.notify_all();
            }
        }
        next += 1;
    }
    actor.shutdown();
}

#[cfg(test)]
mod tests {
    use super::PASS_SLOTS;
    use crate::coordinator::rank::PoisonLatch;

    /// The per-slot poison latch must cover exactly the engine's pass
    /// slots: a clear by pass N+`PASS_SLOTS` reuses pass N's stamp slot,
    /// which is only safe because an epoch's stamp is consumed (or the
    /// pass collected) before its slot's successor starts.
    #[test]
    fn poison_latch_covers_pass_slots() {
        assert_eq!(PASS_SLOTS, PoisonLatch::SLOTS);
    }
}
