//! Ablation (DESIGN.md design-choice): fused per-tile FFN tasks vs the
//! paper's split GEMM0→GEMM1 chain, and processor-count scaling, on the
//! *real* coordinator. Also ablates payload-efficient dispatch by
//! comparing wire rows against the padded bulk-sync baseline.

use std::sync::Arc;

use flashdmoe::config::Config;
use flashdmoe::coordinator::{baseline, DistributedMoE, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::util::stats::{fmt_time, summarize, Table};

fn run_mode(cfg: &Config, mode: TaskGraphMode, passes: usize) -> (f64, u32, usize) {
    let params = Arc::new(ModelParams::generate(cfg, 5));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(cfg));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(cfg, 5, r)).collect();
    let moe = DistributedMoE::new(cfg.clone(), params, backend, mode).unwrap();
    let _ = moe.forward(&inputs).unwrap();
    let mut times = Vec::new();
    let mut tasks = 0;
    let mut depth = 0;
    for _ in 0..passes {
        let r = moe.forward(&inputs).unwrap();
        times.push(r.metrics.wall_secs);
        tasks = r.metrics.ranks.iter().map(|x| x.total_tasks()).sum();
        depth = r.metrics.ranks.iter().map(|x| x.max_queue_depth).max().unwrap();
    }
    (summarize(&times).p50, tasks, depth)
}

fn main() {
    let passes: usize = std::env::var("PASSES").ok().and_then(|v| v.parse().ok()).unwrap_or(5);

    println!("## Ablation A — task granularity (fused tile-FFN vs split GEMM chain)\n");
    let mut t = Table::new(&["preset", "mode", "p50 latency", "tasks", "max queue depth"]);
    for preset in ["tiny", "default"] {
        let cfg = Config::preset(preset).unwrap();
        for (name, mode) in [("fused", TaskGraphMode::Fused), ("split", TaskGraphMode::Split)] {
            let (p50, tasks, depth) = run_mode(&cfg, mode, passes);
            t.row(&[preset.into(), name.into(), fmt_time(p50), tasks.to_string(), depth.to_string()]);
        }
    }
    println!("{}", t.render());

    println!("\n## Ablation B — processor actors per rank (work-conserving scheduler scaling)\n");
    let mut t = Table::new(&["processors", "p50 latency"]);
    for procs in [1usize, 2, 4, 8] {
        let mut cfg = Config::preset("default").unwrap();
        cfg.set("processors", &procs.to_string()).unwrap();
        let (p50, _, _) = run_mode(&cfg, TaskGraphMode::Fused, passes);
        t.row(&[procs.to_string(), fmt_time(p50)]);
    }
    println!("{}", t.render());

    println!("\n## Ablation C — payload efficiency (valid rows vs padded rows on the wire)\n");
    let cfg = Config::preset("default").unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, 5));
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let inputs: Vec<Vec<f32>> =
        (0..cfg.system.ranks).map(|r| generate_tokens(&cfg, 5, r)).collect();
    let moe =
        DistributedMoE::new(cfg.clone(), params.clone(), backend.clone(), TaskGraphMode::Fused)
            .unwrap();
    let flash = moe.forward(&inputs).unwrap();
    let base = baseline::forward_sequential(&cfg, &params, &backend, &inputs).unwrap();
    let flash_rows: usize = flash.metrics.ranks.iter().map(|r| r.sent_rows).sum();
    println!(
        "flash ships {flash_rows} rows; padded bulk-sync ships {} ({} valid) -> {:.1}% of padded traffic avoided",
        base.metrics.sent_rows,
        base.metrics.valid_rows,
        (1.0 - flash_rows as f64 / base.metrics.sent_rows as f64) * 100.0
    );
}
