//! Fig 5a / Fig 11 — SM utilization during the forward pass
//! (T=8K, E=64, 2 GPUs), Nsight-style "SM active" metric — plus the
//! real-execution hot-path A/B: packed vs unpacked compute backend on
//! the resident engine, with the work-stealing pool's queue-contention
//! stats (steals, max depth) and the pack-once audit. Results land in
//! `BENCH_pr3_hotpath.json` (section `engine_ab`).
fn main() {
    let (text, _) = flashdmoe::harness::fig11(42).unwrap();
    println!("{text}");

    let passes: usize =
        std::env::var("PASSES").ok().and_then(|v| v.parse().ok()).unwrap_or(5);
    let (text, points) = flashdmoe::harness::hotpath_ab("default", passes, 42).unwrap();
    println!("{text}");
    flashdmoe::harness::update_bench_json(
        "BENCH_pr3_hotpath.json",
        "engine_ab",
        flashdmoe::harness::hotpath_json(&points),
    )
    .expect("write bench json");
    println!("wrote BENCH_pr3_hotpath.json (section engine_ab)");
}
