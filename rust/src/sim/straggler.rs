//! Straggler-delay simulation (paper §2.1, Table 2, Fig 15).
//!
//! A bulk-synchronous AllToAll step completes when the *slowest* rank
//! finishes; the paper measures the distribution of `t / t_a` where `t_a`
//! is the fastest per-rank kernel time in the step and `t` the step's max.
//! Per-rank kernel times are lognormal around the nominal collective time
//! — sigma models the platform's "software jitter" (commercial VM vs
//! tuned supercomputer).

use crate::util::prng::Rng;
use crate::util::stats::{summarize, Summary};

/// One platform's jitter profile: baseline lognormal sigma plus a
/// heavy-tail mixture (with probability `tail_prob` a rank's kernel is hit
/// by an interfering event — noisy neighbor, page migration, clock
/// throttle — stretching it by `tail_scale`). The tail is what separates
/// the VM's 11.4x p95 from its 3.1x median.
#[derive(Clone, Copy, Debug)]
pub struct Platform {
    pub name: &'static str,
    pub nodes: usize,
    pub gpus: usize,
    pub sigma: f64,
    pub tail_prob: f64,
    pub tail_scale: f64,
    /// Steps profiled (paper: 1750 for the VM, 600 for the supercomputer).
    pub steps: usize,
}

/// Paper Table 2 platforms.
pub fn commercial_vm() -> Platform {
    Platform {
        name: "Commercial VM (V100)",
        nodes: 1,
        gpus: 8,
        sigma: 0.38,
        tail_prob: 0.04,
        tail_scale: 4.0,
        steps: 1750,
    }
}

pub fn supercomputer() -> Platform {
    Platform {
        name: "Supercomputer (A100)",
        nodes: 8,
        gpus: 32,
        sigma: 0.025,
        tail_prob: 0.01,
        tail_scale: 1.25,
        steps: 600,
    }
}

/// Result of a straggler study: the distribution of total/actual ratios.
#[derive(Clone, Debug)]
pub struct StragglerReport {
    pub platform: Platform,
    /// Per-step ratio t / t_a (>= 1).
    pub ratios: Vec<f64>,
    pub summary: Summary,
}

/// Simulate `steps` synchronous AllToAll steps on a platform.
pub fn run(platform: Platform, seed: u64) -> StragglerReport {
    let mut rng = Rng::new(seed);
    let mut ratios = Vec::with_capacity(platform.steps);
    for _ in 0..platform.steps {
        let mut fastest = f64::INFINITY;
        let mut slowest: f64 = 0.0;
        for _ in 0..platform.gpus {
            let mut t = rng.lognormal(0.0, platform.sigma);
            if rng.f64() < platform.tail_prob {
                t *= platform.tail_scale;
            }
            fastest = fastest.min(t);
            slowest = slowest.max(t);
        }
        ratios.push(slowest / fastest);
    }
    let summary = summarize(&ratios);
    StragglerReport { platform, ratios, summary }
}

/// Idle fraction implied by a ratio r: the fastest rank idles (r-1)/r of
/// the step — the time Fig 4's overlapped schedule reclaims.
pub fn idle_fraction(ratio: f64) -> f64 {
    (ratio - 1.0) / ratio
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vm_is_much_worse_than_supercomputer() {
        let vm = run(commercial_vm(), 1);
        let sc = run(supercomputer(), 1);
        assert!(vm.summary.p50 > 2.0, "vm median {}", vm.summary.p50);
        assert!(vm.summary.p95 > 6.0, "vm p95 {}", vm.summary.p95);
        assert!(sc.summary.p50 < 1.25, "sc median {}", sc.summary.p50);
        assert!(sc.summary.p95 < 1.6, "sc p95 {}", sc.summary.p95);
        assert!(vm.summary.p95 > 5.0 * sc.summary.p95);
    }

    #[test]
    fn ratios_are_at_least_one() {
        let rep = run(commercial_vm(), 3);
        assert!(rep.ratios.iter().all(|&r| r >= 1.0));
        assert_eq!(rep.ratios.len(), rep.platform.steps);
    }

    #[test]
    fn idle_fraction_monotone() {
        assert_eq!(idle_fraction(1.0), 0.0);
        assert!(idle_fraction(3.0) > idle_fraction(1.5));
        assert!(idle_fraction(11.0) > 0.9);
    }
}
