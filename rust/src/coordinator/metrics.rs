//! Per-rank, per-pass and engine-lifetime metrics: the measured analogs
//! of the paper's evaluation quantities (SM utilization, latency, payload
//! efficiency, and — for the persistent engine — Table 1's launch count).
//!
//! Three granularities:
//! * [`RankMetrics`]   — one rank, one pass (busy/idle, tasks, traffic).
//! * [`PassMetrics`]   — one epoch-tagged pass across all ranks.
//! * [`EngineMetrics`] — cumulative over a [`MoeEngine`] lifetime:
//!   passes served, steady-state busy/wall, resident thread census, and
//!   the launch-equivalent count, which is exactly 1 — the actors are
//!   launched once at `MoeEngine::start` and every subsequent pass is a
//!   doorbell ring, not a launch.
//!
//! [`MoeEngine`]: super::engine::MoeEngine

/// Fraction of padded dispatch traffic avoided (0.0 when nothing padded).
fn savings(sent_rows: usize, padded_rows: usize) -> f64 {
    if padded_rows == 0 {
        return 0.0;
    }
    1.0 - sent_rows as f64 / padded_rows as f64
}

/// Metrics for one rank over one forward pass.
#[derive(Clone, Debug, Default)]
pub struct RankMetrics {
    /// Sum of processor task-execution time (seconds) across workers.
    pub busy_secs: f64,
    /// Rank wall time for the pass.
    pub wall_secs: f64,
    /// Processor workers on this rank.
    pub processors: usize,
    /// Tasks executed, by kind.
    pub ffn_tasks: u32,
    pub gemm_tasks: u32,
    pub combine_tasks: u32,
    /// Dispatch tiles this rank sent.
    pub tiles_sent: usize,
    /// Valid rows sent vs rows a padded implementation would send.
    pub sent_rows: usize,
    pub padded_rows: usize,
    /// Over-capacity (token, expert) pairs dropped by the gate.
    pub dropped: usize,
    /// One-sided bytes received, split by locality.
    pub bytes_in_local: u64,
    pub bytes_in_remote: u64,
    /// Peak ready-pool depth (scheduling pressure).
    pub max_queue_depth: usize,
    /// Cross-deque task migrations in the work-stealing pool this pass
    /// (includes the subscriber's help-out steals) — the queue-contention
    /// stat: high steals mean the round-robin deal was imbalanced or a
    /// processor ran dry while a peer was backed up.
    pub steals: u32,
}

impl RankMetrics {
    /// Processor-utilization analog of the paper's SM utilization: the
    /// fraction of processor-seconds spent executing tasks.
    pub fn utilization(&self) -> f64 {
        if self.wall_secs == 0.0 || self.processors == 0 {
            return 0.0;
        }
        (self.busy_secs / (self.wall_secs * self.processors as f64)).min(1.0)
    }

    pub fn total_tasks(&self) -> u32 {
        self.ffn_tasks + self.gemm_tasks + self.combine_tasks
    }

    /// Fraction of padded dispatch traffic avoided (payload efficiency).
    pub fn payload_savings(&self) -> f64 {
        savings(self.sent_rows, self.padded_rows)
    }
}

/// Metrics for one whole forward pass.
#[derive(Clone, Debug, Default)]
pub struct PassMetrics {
    /// The pass epoch this result belongs to (1-based submission order;
    /// also the generation tag stamped into the symmetric heap's flags).
    pub epoch: u64,
    /// End-to-end wall time (max over ranks; the paper's forward latency).
    pub wall_secs: f64,
    pub ranks: Vec<RankMetrics>,
}

impl PassMetrics {
    /// Mean processor utilization across ranks.
    pub fn utilization(&self) -> f64 {
        if self.ranks.is_empty() {
            return 0.0;
        }
        self.ranks.iter().map(|r| r.utilization()).sum::<f64>() / self.ranks.len() as f64
    }

    /// Tokens/s over the pass (throughput, Fig 13's metric).
    pub fn throughput(&self, total_tokens: usize) -> f64 {
        if self.wall_secs == 0.0 {
            return 0.0;
        }
        total_tokens as f64 / self.wall_secs
    }

    pub fn total_bytes(&self) -> u64 {
        self.ranks.iter().map(|r| r.bytes_in_local + r.bytes_in_remote).sum()
    }

    pub fn total_dropped(&self) -> usize {
        self.ranks.iter().map(|r| r.dropped).sum()
    }

    /// Pass-wide payload savings: fraction of padded dispatch traffic
    /// avoided, aggregated over ranks. Under `RoutingPolicy::Dropless` the
    /// padded baseline is the policy's worst-case slot region, so savings
    /// read high exactly when the gate is balanced — and
    /// [`total_dropped`](Self::total_dropped) must read 0 regardless of
    /// skew (asserted by the conformance suite).
    pub fn payload_savings(&self) -> f64 {
        savings(
            self.ranks.iter().map(|r| r.sent_rows).sum(),
            self.ranks.iter().map(|r| r.padded_rows).sum(),
        )
    }
}

/// Cumulative metrics over one persistent engine's lifetime.
#[derive(Clone, Debug, Default)]
pub struct EngineMetrics {
    /// Launch-equivalent count: how many times actor groups were brought
    /// up. Exactly 1 per engine lifetime (Table 1's FlashDMoE row) — a
    /// steady-state pass rings doorbells instead of launching.
    pub launches: u64,
    /// Forward passes served (wait()-collected) so far.
    pub passes: u64,
    /// OS threads ever spawned by this engine (rank actors + resident
    /// processors). Constant after `start`; a growing value would mean a
    /// pass is respawning workers, which the engine never does.
    pub threads_spawned: u64,
    /// Cumulative processor busy seconds across all ranks and passes.
    pub busy_secs: f64,
    /// Cumulative pass wall seconds (sum of per-pass maxima).
    pub wall_secs: f64,
}

impl EngineMetrics {
    /// Steady-state processor utilization over the engine's life so far:
    /// busy processor-seconds over available processor-seconds, with
    /// `workers` = total resident processors across ranks.
    pub fn steady_state_utilization(&self, workers: usize) -> f64 {
        if self.wall_secs == 0.0 || workers == 0 {
            return 0.0;
        }
        (self.busy_secs / (self.wall_secs * workers as f64)).min(1.0)
    }

    /// Launch overhead amortization: launches per pass served. Tends to
    /// zero for a persistent engine; equals 1 for launch-per-call designs.
    pub fn launches_per_pass(&self) -> f64 {
        if self.passes == 0 {
            return self.launches as f64;
        }
        self.launches as f64 / self.passes as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn utilization_bounds() {
        let m = RankMetrics {
            busy_secs: 2.0,
            wall_secs: 1.0,
            processors: 4,
            ..Default::default()
        };
        assert!((m.utilization() - 0.5).abs() < 1e-12);
        let idle = RankMetrics { wall_secs: 1.0, processors: 4, ..Default::default() };
        assert_eq!(idle.utilization(), 0.0);
    }

    #[test]
    fn payload_savings() {
        let m = RankMetrics { sent_rows: 25, padded_rows: 100, ..Default::default() };
        assert!((m.payload_savings() - 0.75).abs() < 1e-12);
    }

    #[test]
    fn pass_throughput() {
        let p = PassMetrics { wall_secs: 0.5, ..Default::default() };
        assert_eq!(p.throughput(1000), 2000.0);
    }

    #[test]
    fn pass_payload_savings_aggregates_ranks() {
        let p = PassMetrics {
            ranks: vec![
                RankMetrics { sent_rows: 10, padded_rows: 50, ..Default::default() },
                RankMetrics { sent_rows: 15, padded_rows: 50, ..Default::default() },
            ],
            ..Default::default()
        };
        assert!((p.payload_savings() - 0.75).abs() < 1e-12);
        assert_eq!(PassMetrics::default().payload_savings(), 0.0);
    }

    #[test]
    fn engine_metrics_amortize_launches() {
        let m = EngineMetrics {
            launches: 1,
            passes: 50,
            threads_spawned: 10,
            busy_secs: 30.0,
            wall_secs: 10.0,
        };
        assert!((m.launches_per_pass() - 0.02).abs() < 1e-12);
        assert!((m.steady_state_utilization(6) - 0.5).abs() < 1e-12);
        let fresh = EngineMetrics { launches: 1, ..Default::default() };
        assert_eq!(fresh.launches_per_pass(), 1.0);
        assert_eq!(fresh.steady_state_utilization(8), 0.0);
    }
}
