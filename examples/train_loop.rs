//! End-to-end training driver: the paper's §5 future-work item
//! (training support) built on this stack — the AOT-compiled
//! `train_step` artifact (MoE layer + linear readout, MSE, SGD; lowered
//! from JAX with its backward pass) is executed from Rust via PJRT for a
//! few hundred steps on a synthetic regression workload, and the loss
//! curve is logged (recorded in EXPERIMENTS.md §Training).
//!
//!     make artifacts && cargo run --release --example train_loop

use flashdmoe::runtime::{ArtifactStore, make_literal};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::fmt_time;

fn main() -> anyhow::Result<()> {
    let preset = std::env::var("PRESET").unwrap_or_else(|_| "tiny".to_string());
    let steps: usize = std::env::var("STEPS").ok().and_then(|v| v.parse().ok()).unwrap_or(300);
    let dir = ArtifactStore::default_dir();
    anyhow::ensure!(
        ArtifactStore::available(&dir),
        "artifacts missing — run `make artifacts` first"
    );
    let store = ArtifactStore::load(&dir, &preset)?;
    let cfg = &store.config;
    let (h, d, e) = (cfg.model.h, cfg.model.d, cfg.model.e);
    let bsz = cfg.system.s_rank;
    println!("train_step artifact: H={h} D={d} E={e} batch={bsz} (lr baked at AOT time)");

    // ---- synthetic regression task: y = tanh(x · w_teacher) --------------
    let mut rng = Rng::new(0x7EAC4);
    let x = rng.normal_vec(bsz * h, 1.0);
    let teacher = rng.normal_vec(h, 0.5);
    let y: Vec<f32> = (0..bsz)
        .map(|i| {
            let dot: f32 = x[i * h..(i + 1) * h].iter().zip(&teacher).map(|(a, b)| a * b).sum();
            dot.tanh()
        })
        .collect();

    // ---- parameter initialization (mirrors python train.init_params) ------
    let mut p = rng.fork(1);
    let mut params: Vec<(Vec<f32>, Vec<usize>)> = vec![
        (p.normal_vec(h * e, 1.0), vec![h, e]),
        (p.normal_vec(e * h * d, 0.1), vec![e, h, d]),
        (vec![0.0; e * d], vec![e, d]),
        (p.normal_vec(e * d * h, 0.1), vec![e, d, h]),
        (vec![0.0; e * h], vec![e, h]),
        (p.normal_vec(h, 0.1), vec![h, 1]),
        (vec![0.0; 1], vec![1]),
    ];

    // ---- training loop: one PJRT execution per step ------------------------
    let kernel = store.kernel("train_step")?;
    let x_lit = make_literal(&x, &[bsz, h])?;
    let y_lit = make_literal(&y, &[bsz, 1])?;
    let t0 = std::time::Instant::now();
    let mut first_loss = f32::NAN;
    let mut last_loss = f32::NAN;
    let mut curve: Vec<(usize, f32)> = Vec::new();
    for step in 0..steps {
        let mut lits = Vec::with_capacity(9);
        for (data, dims) in &params {
            lits.push(make_literal(data, dims)?);
        }
        lits.push(x_lit.clone());
        lits.push(y_lit.clone());
        let outs = kernel.run_literals_tuple(&lits)?;
        anyhow::ensure!(outs.len() == 8, "train_step returns loss + 7 params");
        let loss = outs[0][0];
        if step == 0 {
            first_loss = loss;
        }
        last_loss = loss;
        if step % (steps / 15).max(1) == 0 || step + 1 == steps {
            curve.push((step, loss));
        }
        for (slot, new) in params.iter_mut().zip(&outs[1..]) {
            slot.0.copy_from_slice(new);
        }
        anyhow::ensure!(loss.is_finite(), "loss diverged at step {step}");
    }
    let elapsed = t0.elapsed().as_secs_f64();

    println!("\nstep   loss");
    for (s, l) in &curve {
        let bar = "#".repeat(((l / first_loss).min(1.0) * 50.0) as usize);
        println!("{s:>5}  {l:<10.5} {bar}");
    }
    println!(
        "\n{} steps in {} ({}/step) — loss {:.4} -> {:.4} ({:.1}% reduction)",
        steps,
        fmt_time(elapsed),
        fmt_time(elapsed / steps as f64),
        first_loss,
        last_loss,
        (1.0 - last_loss / first_loss) * 100.0
    );
    anyhow::ensure!(
        last_loss < 0.7 * first_loss,
        "training failed to reduce loss"
    );
    println!("train OK — backward pass + optimizer execute end-to-end from Rust");
    Ok(())
}
