//! The simulated multi-GPU fabric: a PGAS symmetric heap with one-sided,
//! device-initiated transfers (NVSHMEM `putmem_signal` semantics).
//!
//! Every rank owns an identical heap: the symmetric tensor `L` (tile data)
//! plus an array of signal flags. A transfer is `put_signal(src, dst, …)`:
//! copy the payload into the destination's inbox cell, then release-store
//! the flag — the destination's Subscriber observes the flag with an
//! acquire load and may then read the payload (the release/acquire pair is
//! the `nvshmem_fence` analog in Alg. 4's "Enforce memory consistency
//! before consuming packet").
//!
//! **Wire precision.** The heap is the *wire*: cells store elements at
//! the configured [`WirePrecision`] (f32, f16 or bf16), `put_signal`
//! quantizes its f32 payload into that format on the way in, and
//! [`read_into`](SymmetricHeap::read_into) dequantizes back to f32 on the
//! way out — so expert GEMMs and the combine fold always compute in f32
//! while inbox cells, staging regions and the byte counters all scale
//! with the wire element width (a 16-bit wire *measures* half the bytes
//! of f32 for the same routed rows; nothing here is modeled). At `F32`
//! the encode/decode pair is a bitwise byte copy, preserving the
//! pre-existing bitwise-determinism contract exactly. Flag-carried row
//! metadata is unchanged by the format: signals count *rows*, and byte
//! accounting derives bytes as `rows × H × wire.bytes()`.
//!
//! **Pass generations.** The heap is owned by a persistent engine and is
//! never globally reset between forward passes. Instead every signal flag
//! carries a *generation tag* — the pass epoch stamped by the writer —
//! and a subscriber polling for pass `n` treats any flag whose generation
//! is not `n` as empty ([`poll_epoch`](SymmetricHeap::poll_epoch)). Stale
//! flags from pass `n-1` are thus invisible without any global
//! synchronization or flag-clearing sweep, which is what lets pass `n+1`
//! begin the moment the actors are done with pass `n`. Data cells need no
//! clearing either: in-place padding means stale rows are never read (the
//! signal's row count gates consumption). Transfer counters are
//! cumulative over the heap's lifetime; per-pass accounting is done by
//! the rank actors via start-of-pass snapshots.
//!
//! Safety: concurrent raw writes into a shared buffer are sound only
//! because the paper's Theorem 3.1 applies — `put_signal` *enforces* the
//! Definition C.2 validity rules at runtime (returning an error on any
//! forged coordinate), and valid writes from distinct sources are
//! write-write conflict-free by construction. The property test in
//! `rust/tests/properties.rs` fuzzes exactly this argument.

use std::cell::UnsafeCell;
use std::sync::atomic::{AtomicU64, Ordering};

use anyhow::{bail, Result};

use crate::config::WirePrecision;
use crate::layout::{Coord, LayoutDims, Write};
use crate::wire;

/// Signal flag encoding: 0 = never written; otherwise the high 32 bits
/// hold the writer's pass epoch (the per-slot generation counter) and the
/// low 32 bits hold `rows + 1` — the count of valid rows present in the
/// guarded tile (the signal carries the payload-efficiency metadata, like
/// the paper's packet headers).
///
/// **Bound:** `rows` must satisfy `rows < u32::MAX` so that `rows + 1`
/// fits the low 32 bits — otherwise the count would bleed into the epoch
/// half and corrupt the generation tag. The `+ 1` bias also guarantees a
/// written flag can never alias `FLAG_EMPTY`, even at epoch 0 with 0
/// rows. In practice `rows <= bM` (one tile), far below the bound;
/// [`encode_flag`] debug-asserts it anyway.
pub const FLAG_EMPTY: u64 = 0;

/// Encode a (pass epoch, valid rows) pair into a signal flag. See the
/// [`FLAG_EMPTY`] docs for the `rows < u32::MAX` packing bound.
pub fn encode_flag(epoch: u32, rows: usize) -> u64 {
    debug_assert!(
        rows < u32::MAX as usize,
        "rows {rows} overflows the 32-bit valid-row field of the signal flag"
    );
    ((epoch as u64) << 32) | (rows as u64 + 1)
}

/// Valid-row count carried by a non-empty flag.
pub fn flag_rows(flag: u64) -> usize {
    debug_assert_ne!(flag, FLAG_EMPTY);
    ((flag & 0xFFFF_FFFF) as usize) - 1
}

/// Generation (pass epoch) tag carried by a flag.
pub fn flag_epoch(flag: u64) -> u32 {
    (flag >> 32) as u32
}

/// One rank's symmetric heap segment.
struct RankHeap {
    /// The symmetric tensor L, stored as little-endian code units of the
    /// heap's [`WirePrecision`] (4 bytes/elem at f32, 2 at f16/bf16) —
    /// the inbox cells and staging regions genuinely shrink with the
    /// configured width, they are not f32 buffers with narrow accounting.
    /// Backed by `u32` words (length `ceil(elems · width / 4)`) so the
    /// base is 4-byte aligned, which is what lets an f32-wire read be a
    /// zero-copy `&[f32]` borrow ([`SymmetricHeap::read_borrowed`]).
    data: UnsafeCell<Vec<u32>>,
    /// One signal flag per (peer, round, local expert, tile).
    flags: Vec<AtomicU64>,
    /// Transfer accounting (bytes received *at the wire width*), split
    /// per link class — index 0 is intra-node (NVLink-class), index 1 is
    /// inter-node (NIC-class), matching `LinkClass::index()` in the
    /// transport module. Cumulative over the heap's lifetime. Both byte
    /// *and* message counters carry the split, so per-pass snapshots can
    /// never conflate the two classes when both are active in one pass.
    bytes_in: [AtomicU64; 2],
    puts_in: [AtomicU64; 2],
}

/// The whole-fabric symmetric heap. Shared by all rank threads via `Arc`
/// and resident for the owning engine's lifetime.
pub struct SymmetricHeap {
    dims: LayoutDims,
    /// Element format of every cell (fixed at construction).
    wire: WirePrecision,
    ranks: Vec<RankHeap>,
    /// ranks per node, for intra/inter accounting.
    ranks_per_node: usize,
}

// SAFETY: `data` is only mutated through `put_signal`, which enforces the
// Definition C.2 validity rules; valid writes from distinct sources target
// disjoint memory (Theorem 3.1, proved in layout.rs and property-tested),
// and same-source writes are ordered by that source's program order.
// Across passes, the engine's pass-start barrier orders pass n's readers
// before pass n+1's writers on the same cells. Readers synchronize through
// the release-store / acquire-load flag pair.
unsafe impl Sync for SymmetricHeap {}
unsafe impl Send for SymmetricHeap {}

impl SymmetricHeap {
    /// Bitwise-transparent f32-wire heap (the historical default).
    pub fn new(dims: LayoutDims, ranks_per_node: usize) -> Self {
        Self::with_wire(dims, ranks_per_node, WirePrecision::F32)
    }

    /// Heap whose cells, transfers and byte counters all live at `wire`
    /// width. Zero-initialized cells decode to 0.0 in every format.
    pub fn with_wire(dims: LayoutDims, ranks_per_node: usize, wire: WirePrecision) -> Self {
        let cell_words = (dims.elems() * wire.bytes()).div_ceil(4);
        let ranks = (0..dims.p)
            .map(|_| RankHeap {
                data: UnsafeCell::new(vec![0u32; cell_words]),
                flags: (0..dims.num_flags()).map(|_| AtomicU64::new(FLAG_EMPTY)).collect(),
                bytes_in: [AtomicU64::new(0), AtomicU64::new(0)],
                puts_in: [AtomicU64::new(0), AtomicU64::new(0)],
            })
            .collect();
        Self { dims, wire, ranks, ranks_per_node }
    }

    pub fn dims(&self) -> &LayoutDims {
        &self.dims
    }

    /// The heap's wire element format.
    pub fn wire(&self) -> WirePrecision {
        self.wire
    }

    /// Bytes of the symmetric tensor L on one rank at the wire width.
    pub fn bytes_per_rank(&self) -> usize {
        self.dims.elems() * self.wire.bytes()
    }

    /// True when reads need no decode step: an f32 wire on a
    /// little-endian target stores the exact f32 bit patterns, so
    /// [`read_borrowed`](SymmetricHeap::read_borrowed) can hand out the
    /// cell memory directly (the pre-wire-subsystem zero-copy path).
    pub fn zero_copy(&self) -> bool {
        self.wire == WirePrecision::F32 && cfg!(target_endian = "little")
    }

    /// One-sided put + signal: quantize `payload` (rows × H, f32) into
    /// rank `dst`'s cell at `coord` (rows starting at `coord.c`) at the
    /// heap's wire precision, then release-store `encode_flag(epoch,
    /// rows)` into the destination flag for `(coord.p, coord.r, coord.e,
    /// tile)`. `epoch` is the submitting pass's generation tag; the
    /// destination only consumes flags of the generation it is currently
    /// serving. Bytes are accounted at the wire width — `rows × H ×
    /// wire.bytes()` — not at a hardcoded 4 bytes/element.
    ///
    /// Enforces Definition C.2; forged coordinates are rejected, which is
    /// what makes the unsafe interior sound.
    pub fn put_signal(
        &self,
        src: usize,
        dst: usize,
        coord: Coord,
        payload: &[f32],
        epoch: u32,
    ) -> Result<()> {
        self.put_signal_from(src, src, dst, coord, payload, epoch)
    }

    /// One-sided put + signal issued on behalf of a logical source: the
    /// Definition C.2 validity check runs against `src` (whose peer slot
    /// and flags the write targets), while the link class for the
    /// byte/message accounting is derived from `writer` — the rank that
    /// physically issues the transfer. The coalesced inter-node dispatch
    /// uses this for its proxy fan-out: the proxy (on `dst`'s node)
    /// delivers tiles whose coordinates and signals are exactly those of
    /// a direct write from `src` — consumers cannot tell the two apart,
    /// and Theorem 3.1's conflict freedom still holds because cell
    /// disjointness is a function of the *logical* source — but the bytes
    /// count against the writer's intra-node link (the NIC hop was
    /// already accounted, once, by the transport layer).
    pub(crate) fn put_signal_from(
        &self,
        writer: usize,
        src: usize,
        dst: usize,
        coord: Coord,
        payload: &[f32],
        epoch: u32,
    ) -> Result<()> {
        if writer >= self.dims.p {
            bail!("writer rank {writer} out of range (P={})", self.dims.p);
        }
        let h = self.dims.h;
        if payload.is_empty() || payload.len() % h != 0 {
            bail!("payload must be a positive multiple of H={h} floats");
        }
        let rows = payload.len() / h;
        let w = Write { src, dst, coord, rows };
        if !crate::layout::write_is_valid(&w, &self.dims) {
            bail!("invalid one-sided write (Definition C.2): {w:?}");
        }
        if coord.c % self.dims.bm != 0 {
            bail!("tile writes must start at a bM-aligned slot, got c={}", coord.c);
        }
        let target = &self.ranks[dst];
        let wb = self.wire.bytes();
        let off = self.dims.offset(coord) * wb;
        // SAFETY: bounds checked by write_is_valid + offset debug assert;
        // disjointness across concurrent writers by Theorem 3.1 (byte
        // ranges scale element ranges by the constant wire width, so
        // element-disjoint writes stay byte-disjoint). The u32 backing is
        // viewed as bytes for the encode.
        unsafe {
            let base = ((*target.data.get()).as_mut_ptr() as *mut u8).add(off);
            let dst_bytes = std::slice::from_raw_parts_mut(base, payload.len() * wb);
            wire::encode_into(self.wire, payload, dst_bytes);
        }
        // accounting at the wire width (the measured payload-narrowing),
        // per link class of the physical writer -> dst hop
        let bytes = (payload.len() * wb) as u64;
        let class =
            usize::from(writer / self.ranks_per_node != dst / self.ranks_per_node);
        target.bytes_in[class].fetch_add(bytes, Ordering::Relaxed);
        target.puts_in[class].fetch_add(1, Ordering::Relaxed);
        // signal delivery: release pairs with the subscriber's acquire
        let tile = coord.c / self.dims.bm;
        let fidx = self.dims.flag_index(coord.p, coord.r, coord.e, tile);
        target.flags[fidx].store(encode_flag(epoch, rows), Ordering::Release);
        Ok(())
    }

    /// Acquire-load a raw flag on `rank` (generation tag included).
    pub fn poll(&self, rank: usize, flag_idx: usize) -> u64 {
        self.ranks[rank].flags[flag_idx].load(Ordering::Acquire)
    }

    /// Poll a flag for a specific pass generation: `Some(rows)` iff a
    /// packet stamped with `epoch` has arrived. Flags from other passes
    /// (stale generations, or a pipelined writer that raced ahead) read
    /// as empty — this is the per-slot replacement for a global reset.
    pub fn poll_epoch(&self, rank: usize, flag_idx: usize, epoch: u32) -> Option<usize> {
        let flag = self.poll(rank, flag_idx);
        if flag != FLAG_EMPTY && flag_epoch(flag) == epoch {
            Some(flag_rows(flag))
        } else {
            None
        }
    }

    /// Decode `rows` rows at `coord` on `rank` into `out[..rows*H]`
    /// (dequantized to f32 from the wire format; a byte copy at `F32`).
    /// Caller must have observed the guarding flag via
    /// [`poll`]/[`poll_epoch`] (acquire) before reading — that ordering is
    /// what makes this data race-free.
    ///
    /// [`poll`]: SymmetricHeap::poll
    /// [`poll_epoch`]: SymmetricHeap::poll_epoch
    pub fn read_into(&self, rank: usize, coord: Coord, rows: usize, out: &mut [f32]) {
        let wb = self.wire.bytes();
        let off = self.dims.offset(coord) * wb;
        let len = rows * self.dims.h;
        debug_assert!(out.len() >= len, "read_into buffer too small: {} < {len}", out.len());
        // SAFETY: the release/acquire flag protocol orders this read after
        // the producer's copy; the region is never rewritten within a layer
        // pass (slots are owned by one (src, round) pair), and the engine's
        // pass-start barrier orders cross-pass reuse. The u32 backing is
        // viewed as bytes for the decode.
        unsafe {
            let v = &*self.ranks[rank].data.get();
            let bytes = std::slice::from_raw_parts(v.as_ptr() as *const u8, v.len() * 4);
            wire::decode_into(self.wire, &bytes[off..off + len * wb], &mut out[..len]);
        }
    }

    /// Zero-copy read of `rows` rows at `coord` on `rank`: `Some(&[f32])`
    /// iff [`zero_copy`](SymmetricHeap::zero_copy) holds (f32 wire,
    /// little-endian target) — the cell memory *is* the f32 data, so the
    /// hot path pays no decode copy, exactly like the pre-wire-subsystem
    /// `read`. Reduced wires return `None`; callers fall back to
    /// [`read_into`](SymmetricHeap::read_into). Same flag-acquire
    /// precondition as `read_into`.
    pub fn read_borrowed(&self, rank: usize, coord: Coord, rows: usize) -> Option<&[f32]> {
        if !self.zero_copy() {
            return None;
        }
        let off = self.dims.offset(coord);
        let len = rows * self.dims.h;
        // SAFETY: same ordering argument as read_into; the u32 backing
        // guarantees 4-byte alignment, `off` is an element offset (so the
        // byte offset is 4-aligned at f32 width), and on a little-endian
        // target the encoded bytes are the f32 bit patterns verbatim.
        unsafe {
            let v = &*self.ranks[rank].data.get();
            debug_assert!((off + len) * 4 <= v.len() * 4);
            let base = (v.as_ptr() as *const f32).add(off);
            Some(std::slice::from_raw_parts(base, len))
        }
    }

    /// Allocating convenience wrapper over [`read_into`] (tests, cold
    /// paths; the hot path reuses per-worker buffers instead).
    ///
    /// [`read_into`]: SymmetricHeap::read_into
    pub fn read_rows(&self, rank: usize, coord: Coord, rows: usize) -> Vec<f32> {
        let mut out = vec![0.0f32; rows * self.dims.h];
        self.read_into(rank, coord, rows, &mut out);
        out
    }

    /// (intra-node, inter-node) bytes received by `rank` over the heap's
    /// lifetime.
    pub fn bytes_in(&self, rank: usize) -> (u64, u64) {
        (
            self.ranks[rank].bytes_in[0].load(Ordering::Relaxed),
            self.ranks[rank].bytes_in[1].load(Ordering::Relaxed),
        )
    }

    /// (intra-node, inter-node) one-sided messages received by `rank`
    /// over the heap's lifetime.
    pub fn puts_in_split(&self, rank: usize) -> (u64, u64) {
        (
            self.ranks[rank].puts_in[0].load(Ordering::Relaxed),
            self.ranks[rank].puts_in[1].load(Ordering::Relaxed),
        )
    }

    /// One-sided messages received by `rank` over the heap's lifetime
    /// (both link classes).
    pub fn puts_in(&self, rank: usize) -> u64 {
        let (intra, inter) = self.puts_in_split(rank);
        intra + inter
    }

    /// Total bytes moved across the fabric over the heap's lifetime.
    pub fn total_bytes(&self) -> u64 {
        (0..self.dims.p)
            .map(|r| {
                let (l, rm) = self.bytes_in(r);
                l + rm
            })
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::Arc;

    fn heap() -> SymmetricHeap {
        SymmetricHeap::new(LayoutDims { p: 2, e_local: 2, c: 8, h: 4, bm: 4 }, 2)
    }

    #[test]
    fn put_then_poll_then_read_roundtrips() {
        let h = heap();
        let coord = Coord { p: 0, r: 0, b: 1, e: 1, c: 4 };
        let payload: Vec<f32> = (0..8).map(|i| i as f32).collect(); // 2 rows
        h.put_signal(0, 1, coord, &payload, 1).unwrap();
        let fidx = h.dims().flag_index(0, 0, 1, 1);
        let flag = h.poll(1, fidx);
        assert_eq!(flag_rows(flag), 2);
        assert_eq!(flag_epoch(flag), 1);
        assert_eq!(h.poll_epoch(1, fidx, 1), Some(2));
        assert_eq!(h.read_rows(1, coord, 2), payload, "f32 wire roundtrips bitwise");
    }

    #[test]
    fn flag_encoding_roundtrips_and_never_aliases_empty() {
        for (epoch, rows) in [(0u32, 0usize), (1, 7), (u32::MAX, 12345), (42, u32::MAX as usize - 1)] {
            let f = encode_flag(epoch, rows);
            assert_ne!(f, FLAG_EMPTY, "written flag must never read as empty");
            assert_eq!(flag_epoch(f), epoch);
            assert_eq!(flag_rows(f), rows);
        }
    }

    #[test]
    #[should_panic(expected = "overflows the 32-bit valid-row field")]
    #[cfg(debug_assertions)]
    fn flag_encoding_rejects_row_overflow() {
        let _ = encode_flag(1, u32::MAX as usize);
    }

    #[test]
    fn forged_coordinates_rejected() {
        let h = heap();
        // src 0 claiming peer slot 1 (forged p)
        let bad = Coord { p: 1, r: 0, b: 1, e: 0, c: 0 };
        assert!(h.put_signal(0, 1, bad, &[0.0; 4], 1).is_err());
        // staging write to another rank (b=0, src != dst)
        let stage = Coord { p: 0, r: 0, b: 0, e: 0, c: 0 };
        assert!(h.put_signal(0, 1, stage, &[0.0; 4], 1).is_err());
        // unaligned tile start
        let unaligned = Coord { p: 0, r: 0, b: 1, e: 0, c: 2 };
        assert!(h.put_signal(0, 1, unaligned, &[0.0; 4], 1).is_err());
        // ragged payload
        let good = Coord { p: 0, r: 0, b: 1, e: 0, c: 0 };
        assert!(h.put_signal(0, 1, good, &[0.0; 3], 1).is_err());
    }

    #[test]
    fn stale_generations_read_as_empty() {
        let h = heap();
        let coord = Coord { p: 0, r: 0, b: 1, e: 0, c: 0 };
        let fidx = h.dims().flag_index(0, 0, 0, 0);
        // never-written flag is empty for every generation
        assert_eq!(h.poll_epoch(1, fidx, 1), None);
        // pass 1 writes 1 row
        h.put_signal(0, 1, coord, &[1.0; 4], 1).unwrap();
        assert_eq!(h.poll_epoch(1, fidx, 1), Some(1));
        // pass 2's subscriber must not see pass 1's flag...
        assert_eq!(h.poll_epoch(1, fidx, 2), None);
        // ...until the slot is rewritten with generation 2 (2 rows now)
        h.put_signal(0, 1, coord, &[2.0; 8], 2).unwrap();
        assert_eq!(h.poll_epoch(1, fidx, 2), Some(2));
        assert_eq!(h.poll_epoch(1, fidx, 1), None, "old generation invisible");
        assert!(h.read_rows(1, coord, 2).iter().all(|&v| v == 2.0));
    }

    #[test]
    fn concurrent_puts_from_distinct_sources_are_race_free() {
        let dims = LayoutDims { p: 8, e_local: 2, c: 16, h: 8, bm: 4 };
        let h = Arc::new(SymmetricHeap::new(dims, 8));
        let mut handles = Vec::new();
        for src in 0..8usize {
            let h = h.clone();
            handles.push(std::thread::spawn(move || {
                for e in 0..2 {
                    for t in 0..4 {
                        let coord = Coord { p: src, r: 0, b: 1, e, c: t * 4 };
                        let val = (src * 100 + e * 10 + t) as f32;
                        h.put_signal(src, 0, coord, &vec![val; 4 * 8], 1).unwrap();
                    }
                }
            }));
        }
        for hd in handles {
            hd.join().unwrap();
        }
        // every cell holds its writer's value
        for src in 0..8usize {
            for e in 0..2 {
                for t in 0..4 {
                    let coord = Coord { p: src, r: 0, b: 1, e, c: t * 4 };
                    let fidx = h.dims().flag_index(src, 0, e, t);
                    assert_eq!(h.poll_epoch(0, fidx, 1), Some(4));
                    let want = (src * 100 + e * 10 + t) as f32;
                    assert!(h.read_rows(0, coord, 4).iter().all(|&v| v == want));
                }
            }
        }
        assert_eq!(h.puts_in(0), 8 * 2 * 4);
    }

    #[test]
    fn reduced_precision_wire_quantizes_payloads_and_halves_accounting() {
        let dims = LayoutDims { p: 2, e_local: 2, c: 8, h: 4, bm: 4 };
        let coord = Coord { p: 0, r: 0, b: 1, e: 1, c: 4 };
        // payload mixes exactly-representable values with ones that must
        // round; 2 rows x H=4 = 8 floats
        let payload: Vec<f32> = vec![1.0, -2.5, 0.15625, 1024.0, 1.0e-3, -7.3, 3.14159, 0.0];
        let f32_bytes = {
            let h = SymmetricHeap::new(dims, 2);
            h.put_signal(0, 1, coord, &payload, 1).unwrap();
            h.total_bytes()
        };
        assert_eq!(f32_bytes, 8 * 4);
        for wire in [WirePrecision::Bf16, WirePrecision::F16] {
            let h = SymmetricHeap::with_wire(dims, 2, wire);
            assert_eq!(h.wire(), wire);
            assert_eq!(h.bytes_per_rank(), dims.elems() * 2, "cells shrink for real");
            h.put_signal(0, 1, coord, &payload, 1).unwrap();
            // measured bytes are exactly half of the f32 wire for the
            // same rows — the accounting follows the element width
            assert_eq!(h.total_bytes() * 2, f32_bytes, "{wire:?} byte accounting");
            // the receiver observes the per-element quantized values
            let got = h.read_rows(1, coord, 2);
            for (g, &x) in got.iter().zip(&payload) {
                assert_eq!(
                    g.to_bits(),
                    crate::wire::quantize(wire, x).to_bits(),
                    "{wire:?}: wire roundtrip of {x}"
                );
            }
            // flags still carry rows, independent of the element width
            let fidx = h.dims().flag_index(0, 0, 1, 1);
            assert_eq!(h.poll_epoch(1, fidx, 1), Some(2));
            // reduced wires have no zero-copy view — callers must decode
            assert!(!h.zero_copy());
            assert!(h.read_borrowed(1, coord, 2).is_none());
        }
    }

    #[test]
    fn f32_wire_reads_borrow_zero_copy() {
        let h = heap(); // f32 wire
        let coord = Coord { p: 0, r: 0, b: 1, e: 0, c: 0 };
        let payload = vec![1.5f32, -2.0, f32::MIN_POSITIVE, 0.0, 3.25, -0.0, 1e30, -7.0];
        h.put_signal(0, 1, coord, &payload, 1).unwrap();
        if cfg!(target_endian = "little") {
            assert!(h.zero_copy());
            let got = h.read_borrowed(1, coord, 2).expect("f32 wire borrows");
            // bitwise: the borrow views the encoded cell directly
            for (g, w) in got.iter().zip(&payload) {
                assert_eq!(g.to_bits(), w.to_bits());
            }
        }
        // the decode path agrees with the borrow path
        assert_eq!(h.read_rows(1, coord, 2), payload);
    }

    #[test]
    fn locality_accounting_splits_intra_inter() {
        // 4 ranks, 2 per node
        let dims = LayoutDims { p: 4, e_local: 1, c: 4, h: 2, bm: 4 };
        let h = SymmetricHeap::new(dims, 2);
        let c = |p| Coord { p, r: 0, b: 1, e: 0, c: 0 };
        h.put_signal(1, 0, c(1), &vec![0.0; 8], 1).unwrap(); // same node (0,1)
        h.put_signal(2, 0, c(2), &vec![0.0; 8], 1).unwrap(); // cross node
        let (local, remote) = h.bytes_in(0);
        assert_eq!(local, 32);
        assert_eq!(remote, 32);
        // counters are cumulative — a second pass adds on top, and the
        // per-pass view is a snapshot delta (taken by the rank actors)
        h.put_signal(1, 0, c(1), &vec![0.0; 8], 2).unwrap();
        assert_eq!(h.bytes_in(0), (64, 32));
        assert_eq!(h.total_bytes(), 96);
        // message counters carry the same per-class split as the bytes
        assert_eq!(h.puts_in_split(0), (2, 1));
        assert_eq!(h.puts_in(0), 3);
    }

    #[test]
    fn delegated_writes_validate_source_but_account_writer() {
        // 4 ranks, 2 per node; rank 2 (same node as 3) delivers rank 0's
        // tile to rank 3 — the proxy fan-out half of a coalesced transfer
        let dims = LayoutDims { p: 4, e_local: 1, c: 4, h: 2, bm: 4 };
        let h = SymmetricHeap::new(dims, 2);
        let c0 = Coord { p: 0, r: 0, b: 1, e: 0, c: 0 };
        h.put_signal_from(2, 0, 3, c0, &[1.0; 8], 3).unwrap();
        // consumers observe an ordinary packet from rank 0
        let fidx = dims.flag_index(0, 0, 0, 0);
        assert_eq!(h.poll_epoch(3, fidx, 3), Some(4));
        assert_eq!(h.read_rows(3, c0, 4), vec![1.0; 8]);
        // ...but the bytes/messages count on the writer's intra-node link
        assert_eq!(h.bytes_in(3), (32, 0));
        assert_eq!(h.puts_in_split(3), (1, 0));
        // validity is still judged against the logical source: a proxy
        // cannot forge a write into some third rank's peer slot
        let forged = Coord { p: 2, r: 0, b: 1, e: 0, c: 0 };
        assert!(h.put_signal_from(2, 0, 3, forged, &[0.0; 2], 3).is_err());
        // and the physical writer must be a real rank
        assert!(h.put_signal_from(9, 0, 3, c0, &[0.0; 2], 3).is_err());
    }
}
