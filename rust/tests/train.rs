//! Training conformance: the engine's backward pass (Dgrad/Wgrad tile
//! tasks through the persistent scheduler, reverse-wire transfers)
//! against the dense autograd oracle and central finite differences;
//! bitwise wgrad determinism across restarts and processor counts;
//! stash lifecycle errors; and the `Trainer` loop (accumulation windows,
//! optimizer updates, loss-goes-down).

use std::sync::Arc;

use flashdmoe::config::{Config, RoutingPolicy, WirePrecision};
use flashdmoe::coordinator::rank::STASH_CAP;
use flashdmoe::coordinator::{BackwardResult, MoeEngine, TaskGraphMode};
use flashdmoe::expert::{generate_tokens, ModelParams};
use flashdmoe::harness::multinode_config;
use flashdmoe::runtime::{ComputeBackend, NativeBackend};
use flashdmoe::train::{GradStore, Optimizer, Trainer};
use flashdmoe::util::check::{dense_reference_moe, dense_reference_moe_grad};
use flashdmoe::util::prng::Rng;
use flashdmoe::util::stats::max_abs_diff;

fn train_cfg(preset: &str) -> Config {
    let mut cfg = Config::preset(preset).unwrap();
    cfg.set("train", "on").unwrap();
    cfg.validate().unwrap();
    cfg
}

fn start(cfg: &Config, params: &Arc<ModelParams>) -> MoeEngine {
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(cfg));
    MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Fused).unwrap()
}

fn rank_inputs(cfg: &Config, seed: u64) -> Vec<Vec<f32>> {
    (0..cfg.system.ranks).map(|r| generate_tokens(cfg, seed, r)).collect()
}

/// Deterministic pseudo output-gradients, one buffer per rank, shaped
/// like the forward outputs.
fn rank_grads(shapes: &[Vec<f32>], seed: u64) -> Vec<Vec<f32>> {
    let mut rng = Rng::new(seed);
    shapes.iter().map(|o| rng.normal_vec(o.len(), 1.0)).collect()
}

/// Dense oracle over every rank: per-rank dX plus the summed GradStore
/// (each rank gates and routes its own batch independently, so the
/// whole-layer parameter gradient is the sum of per-rank contributions).
fn dense_grads(
    cfg: &Config,
    params: &ModelParams,
    inputs: &[Vec<f32>],
    dy: &[Vec<f32>],
) -> (Vec<Vec<f32>>, GradStore) {
    let mut total = GradStore::zeros(cfg.model.h, cfg.model.d, cfg.model.e);
    let mut dxs = Vec::with_capacity(inputs.len());
    for (a, g) in inputs.iter().zip(dy) {
        let (dx, gs) = dense_reference_moe_grad(cfg, params, a, g);
        total.add_assign(&gs);
        dxs.push(dx);
    }
    (dxs, total)
}

fn store_max_diff(a: &GradStore, b: &GradStore) -> f32 {
    a.tensors()
        .iter()
        .zip(b.tensors())
        .map(|(x, y)| max_abs_diff(x, y))
        .fold(0.0f32, f32::max)
}

fn assert_store_bits_eq(a: &GradStore, b: &GradStore, what: &str) {
    for (t, (x, y)) in a.tensors().iter().zip(b.tensors()).enumerate() {
        assert_eq!(x.len(), y.len(), "{what}: tensor {t} length");
        for (i, (u, v)) in x.iter().zip(y).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "{what}: tensor {t} element {i} bit pattern");
        }
    }
}

/// Run one stashed forward + backward on a fresh engine, returning the
/// backward result and the seeded output-gradients it was driven with.
fn fwd_bwd(
    cfg: &Config,
    params: &Arc<ModelParams>,
    inputs: &[Vec<f32>],
    dy_seed: u64,
) -> (BackwardResult, Vec<Vec<f32>>) {
    let engine = start(cfg, params);
    let fwd = engine.submit(inputs).unwrap().wait().unwrap();
    assert_eq!(fwd.metrics.total_dropped(), 0, "conformance runs must not drop");
    let dy = rank_grads(&fwd.outputs, dy_seed);
    let bwd = engine.backward(fwd.metrics.epoch, &dy).unwrap();
    (bwd, dy)
}

#[test]
fn backward_matches_dense_reference_across_policies() {
    // acceptance: engine dX and GradStore equal the dense autograd
    // oracle at 1e-4 on the exact f32 wire, under both routing policies
    // (ample capacity so nothing drops and engine == dense).
    for policy in [RoutingPolicy::Capacity(8.0), RoutingPolicy::Dropless] {
        let mut cfg = train_cfg("tiny");
        cfg.model.policy = policy;
        cfg.validate().unwrap();
        let params = Arc::new(ModelParams::generate(&cfg, 0x7A1));
        let inputs = rank_inputs(&cfg, 0x7A1);
        let engine = start(&cfg, &params);
        let fwd = engine.submit(&inputs).unwrap().wait().unwrap();
        assert_eq!(fwd.metrics.total_dropped(), 0, "{policy:?}: ample capacity dropped");
        assert!(fwd.metrics.gate_entropy() > 0.0, "{policy:?}: gate entropy not stamped");
        let dy = rank_grads(&fwd.outputs, 0x7A2);
        let bwd = engine.backward(fwd.metrics.epoch, &dy).unwrap();

        let (dx_ref, grads_ref) = dense_grads(&cfg, &params, &inputs, &dy);
        for (r, (got, want)) in bwd.input_grads.iter().zip(&dx_ref).enumerate() {
            let diff = max_abs_diff(got, want);
            assert!(diff < 1e-4, "{policy:?} rank {r}: dX diff {diff} vs dense oracle");
        }
        let gdiff = store_max_diff(&bwd.grads, &grads_ref);
        assert!(gdiff < 1e-4, "{policy:?}: GradStore diff {gdiff} vs dense oracle");

        // direction split + task accounting: the backward pass reports
        // its bytes as reverse traffic and ran Dgrad/Wgrad tile tasks
        assert!(bwd.metrics.backward, "{policy:?}: backward flag");
        assert!(bwd.metrics.reverse_bytes() > 0, "{policy:?}: reverse bytes");
        assert_eq!(bwd.metrics.forward_bytes(), 0, "{policy:?}: forward bytes on a backward");
        let dgrad: u32 = bwd.metrics.ranks.iter().map(|m| m.dgrad_tasks).sum();
        let wgrad: u32 = bwd.metrics.ranks.iter().map(|m| m.wgrad_tasks).sum();
        assert!(dgrad > 0 && wgrad > 0, "{policy:?}: dgrad={dgrad} wgrad={wgrad}");
        let em = engine.metrics();
        assert_eq!((em.passes, em.backward_passes), (1, 1), "{policy:?}: pass split");
        assert_eq!(em.reverse_bytes, bwd.metrics.total_bytes(), "{policy:?}: reverse byte ledger");
        assert!(em.forward_bytes > 0, "{policy:?}: lifetime forward bytes");
    }
}

/// Compare an analytic gradient coordinate against central differences
/// of `eval(shift)` = L(θ + shift·e_c). Because the gated loss is only
/// piecewise smooth (top-k selection), a probe whose FD estimates at ε
/// and ε/2 disagree sits on a routing boundary (or in f32 noise) and is
/// skipped — the caller asserts a minimum number of checkable probes.
/// Returns true when the coordinate was checkable.
fn fd_probe(eval: &dyn Fn(f32) -> f64, analytic: f64, eps: f32, slack: f64, what: &str) -> bool {
    let central = |e: f32| (eval(e) - eval(-e)) / (2.0 * e as f64);
    let f1 = central(eps);
    let f2 = central(eps / 2.0);
    if (f1 - f2).abs() > 0.1 * f1.abs().max(f2.abs()).max(1.0) {
        return false; // non-smooth neighborhood: top-k flip under the probe
    }
    let tol = 1e-2 * f2.abs().max(analytic.abs()) + slack;
    assert!((f2 - analytic).abs() <= tol, "{what}: fd {f2} vs analytic {analytic}");
    true
}

#[test]
fn dense_oracle_matches_central_finite_differences_on_fuzzed_shapes() {
    // validate the oracle itself: on small fuzzed shapes, sampled
    // parameter and input coordinates of `dense_reference_moe_grad` must
    // agree with central differences of L(θ) = Σ dy ⊙ out(θ).
    for (case, &(h, d, e, k, s)) in
        [(8usize, 16usize, 4usize, 2usize, 6usize), (12, 8, 6, 3, 5), (16, 16, 8, 1, 9)]
            .iter()
            .enumerate()
    {
        let mut cfg = Config::preset("tiny").unwrap();
        cfg.set("h", &h.to_string()).unwrap();
        cfg.set("d", &d.to_string()).unwrap();
        cfg.set("e", &e.to_string()).unwrap();
        cfg.set("k", &k.to_string()).unwrap();
        cfg.set("routing_policy", "dropless").unwrap();
        cfg.validate().unwrap();
        let params = ModelParams::generate(&cfg, 0xFD0 + case as u64);
        let mut rng = Rng::new(0xFD1 + case as u64);
        let a = rng.normal_vec(s * h, 1.0);
        let dy = rng.normal_vec(s * h, 1.0);
        let (dx, grads) = dense_reference_moe_grad(&cfg, &params, &a, &dy);
        let loss = |p: &ModelParams, x: &[f32]| -> f64 {
            dense_reference_moe(&cfg, p, x)
                .iter()
                .zip(&dy)
                .map(|(&o, &g)| (o as f64) * (g as f64))
                .sum()
        };
        let (mut checked, mut probes) = (0usize, 0usize);
        // parameter coordinates: a handful per tensor, fixed stride
        let gt = grads.tensors();
        for (t, g) in gt.iter().enumerate() {
            let stride = (g.len() / 5).max(1);
            for c in (0..g.len()).step_by(stride).take(5) {
                let eval = |shift: f32| {
                    let mut p = params.clone();
                    flashdmoe::train::param_tensors_mut(&mut p)[t][c] += shift;
                    loss(&p, &a)
                };
                probes += 1;
                checked += usize::from(fd_probe(
                    &eval,
                    g[c] as f64,
                    1e-2,
                    1e-3,
                    &format!("case {case} tensor {t}[{c}]"),
                ));
            }
        }
        // input coordinates
        for c in (0..a.len()).step_by((a.len() / 7).max(1)).take(7) {
            let eval = |shift: f32| {
                let mut x = a.clone();
                x[c] += shift;
                loss(&params, &x)
            };
            probes += 1;
            checked += usize::from(fd_probe(
                &eval,
                dx[c] as f64,
                1e-2,
                1e-3,
                &format!("case {case} input[{c}]"),
            ));
        }
        // the boundary skip must stay the exception, not the rule
        assert!(
            checked * 2 > probes,
            "case {case}: only {checked}/{probes} probes were checkable"
        );
    }
}

#[test]
fn engine_gradients_match_finite_differences_end_to_end() {
    // probe the *live engine* with central differences: perturb an input
    // coordinate (fresh pass) and a parameter coordinate (update_params
    // round-trip) and compare dL against the engine's own backward.
    let mut cfg = train_cfg("tiny");
    cfg.set("routing_policy", "dropless").unwrap();
    cfg.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, 0xE2E));
    let inputs = rank_inputs(&cfg, 0xE2E);
    let engine = start(&cfg, &params);
    let fwd = engine.submit(&inputs).unwrap().wait().unwrap();
    let dy = rank_grads(&fwd.outputs, 0xE2F);
    let bwd = engine.backward(fwd.metrics.epoch, &dy).unwrap();
    let loss = |outputs: &[Vec<f32>]| -> f64 {
        outputs
            .iter()
            .zip(&dy)
            .flat_map(|(o, g)| o.iter().zip(g))
            .map(|(&o, &g)| (o as f64) * (g as f64))
            .sum()
    };
    let (mut checked, mut probes) = (0usize, 0usize);
    // input coordinates on two ranks (each eval is a fresh engine pass)
    for (rank, coord) in [(0usize, 5usize), (1, 131)] {
        let eval = |shift: f32| {
            let mut x = inputs.clone();
            x[rank][coord] += shift;
            loss(&engine.submit(&x).unwrap().wait().unwrap().outputs)
        };
        probes += 1;
        checked += usize::from(fd_probe(
            &eval,
            bwd.input_grads[rank][coord] as f64,
            1e-2,
            2e-2,
            &format!("input rank {rank}[{coord}]"),
        ));
    }
    // parameter coordinates through update_params (also exercises the
    // epoch-fenced weight swap + backend refresh)
    for (t, c, what) in
        [(0usize, 3usize, "wg[3]"), (1, 17, "expert0.w1[17]"), (4, 2, "expert0.b2[2]")]
    {
        let eval = |shift: f32| {
            let mut p = params.as_ref().clone();
            flashdmoe::train::param_tensors_mut(&mut p)[t][c] += shift;
            engine.update_params(p).unwrap();
            loss(&engine.submit(&inputs).unwrap().wait().unwrap().outputs)
        };
        probes += 1;
        checked += usize::from(fd_probe(&eval, bwd.grads.tensors()[t][c] as f64, 1e-2, 2e-2, what));
        engine.update_params(params.as_ref().clone()).unwrap(); // restore
    }
    assert!(checked * 2 > probes, "only {checked}/{probes} engine probes were checkable");
}

#[test]
fn wgrad_is_bitwise_identical_across_restarts_and_processor_counts() {
    // acceptance: the ordinal-gated fold makes every gradient tensor —
    // not just the outputs — bitwise reproducible whatever the worker
    // count or steal schedule, and across engine restarts.
    let mut cfg0 = train_cfg("tiny");
    cfg0.set("routing_policy", "dropless").unwrap();
    cfg0.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg0, 0xB17));
    let inputs = rank_inputs(&cfg0, 0xB17);
    let mut golden: Option<BackwardResult> = None;
    for processors in [1usize, 4, 8] {
        let mut cfg = cfg0.clone();
        cfg.set("processors", &processors.to_string()).unwrap();
        for restart in 0..2 {
            let (bwd, _) = fwd_bwd(&cfg, &params, &inputs, 0xB18);
            match &golden {
                None => golden = Some(bwd),
                Some(g) => {
                    let tag = format!("processors={processors} restart={restart}");
                    assert_store_bits_eq(&g.grads, &bwd.grads, &tag);
                    for (r, (x, y)) in g.input_grads.iter().zip(&bwd.input_grads).enumerate() {
                        for (i, (u, v)) in x.iter().zip(y).enumerate() {
                            assert_eq!(
                                u.to_bits(),
                                v.to_bits(),
                                "{tag}: rank {r} dX[{i}] bit pattern"
                            );
                        }
                    }
                }
            }
        }
    }
}

#[test]
fn hierarchical_backward_matches_flat_and_dense_reference() {
    // the reverse scatter rides the node-coalesced transport too: on a
    // 4-node topology, hierarchical backward must equal flat backward
    // bit for bit, and both must match the dense oracle at 1e-4.
    let mut cfg = multinode_config(48).unwrap();
    cfg.set("train", "on").unwrap();
    cfg.set("routing_policy", "dropless").unwrap();
    cfg.validate().unwrap();
    assert!(cfg.system.dispatch.is_hierarchical(), "preset default");
    let params = Arc::new(ModelParams::generate(&cfg, 0x4E0D));
    let inputs = rank_inputs(&cfg, 0x4E0D);
    let mut flat_cfg = cfg.clone();
    flat_cfg.set("dispatch", "flat").unwrap();
    let (hier, dy) = fwd_bwd(&cfg, &params, &inputs, 0x4E0E);
    let (flat, _) = fwd_bwd(&flat_cfg, &params, &inputs, 0x4E0E);
    assert_store_bits_eq(&flat.grads, &hier.grads, "flat vs hierarchical wgrad");
    for (r, (f, h)) in flat.input_grads.iter().zip(&hier.input_grads).enumerate() {
        for (i, (u, v)) in f.iter().zip(h).enumerate() {
            assert_eq!(u.to_bits(), v.to_bits(), "rank {r} dX[{i}]: flat vs hierarchical");
        }
    }
    let (dx_ref, grads_ref) = dense_grads(&cfg, &params, &inputs, &dy);
    let gdiff = store_max_diff(&hier.grads, &grads_ref);
    assert!(gdiff < 1e-4, "multi-node GradStore diff {gdiff} vs dense oracle");
    for (r, (got, want)) in hier.input_grads.iter().zip(&dx_ref).enumerate() {
        let diff = max_abs_diff(got, want);
        assert!(diff < 1e-4, "multi-node rank {r}: dX diff {diff} vs dense oracle");
    }
}

#[test]
fn reduced_precision_wire_halves_reverse_bytes_and_stays_close() {
    // the 16-bit wire applies to gradient traffic too: identical routing
    // means the measured reverse bytes halve *exactly*, quantization
    // genuinely happens, and the gradients stay close to the f32 arm
    // in relative Frobenius norm.
    let mut cfg = train_cfg("tiny");
    cfg.set("routing_policy", "dropless").unwrap();
    cfg.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, 0x16B));
    let inputs = rank_inputs(&cfg, 0x16B);
    let (exact, _) = fwd_bwd(&cfg, &params, &inputs, 0x16C);
    for wire in [WirePrecision::Bf16, WirePrecision::F16] {
        let mut cfg_w = cfg.clone();
        cfg_w.set("wire_precision", wire.name()).unwrap();
        let (got, _) = fwd_bwd(&cfg_w, &params, &inputs, 0x16C);
        assert_eq!(
            got.metrics.reverse_bytes() * 2,
            exact.metrics.reverse_bytes(),
            "{wire:?}: reverse bytes must halve for identical routing"
        );
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        let mut any_diff = false;
        for (x, y) in got.grads.tensors().iter().zip(exact.grads.tensors()) {
            for (u, v) in x.iter().zip(y) {
                num += ((u - v) as f64).powi(2);
                den += (*v as f64).powi(2);
                any_diff |= u.to_bits() != v.to_bits();
            }
        }
        let rel = (num / den.max(1e-30)).sqrt();
        assert!(rel < 0.05, "{wire:?}: wgrad relative error {rel} vs f32 wire");
        assert!(any_diff, "{wire:?}: gradients identical to f32 — quantization is a no-op?");
    }
}

#[test]
fn stash_lifecycle_and_mode_errors() {
    let cfg_plain = Config::preset("tiny").unwrap();
    let params = Arc::new(ModelParams::generate(&cfg_plain, 0x5A5));
    let inputs = rank_inputs(&cfg_plain, 0x5A5);
    // no train=on: backward refused up front
    let engine = start(&cfg_plain, &params);
    let fwd = engine.submit(&inputs).unwrap().wait().unwrap();
    let dy = rank_grads(&fwd.outputs, 1);
    let err = engine.backward(fwd.metrics.epoch, &dy).unwrap_err().to_string();
    assert!(err.contains("train=on"), "unexpected error: {err}");
    engine.shutdown();

    let cfg = train_cfg("tiny");
    let engine = start(&cfg, &params);
    // eviction: the stash keeps the last STASH_CAP passes only
    let first = engine.submit(&inputs).unwrap().wait().unwrap();
    for _ in 0..STASH_CAP {
        engine.submit(&inputs).unwrap().wait().unwrap();
    }
    let err = engine.backward(first.metrics.epoch, &dy).unwrap_err().to_string();
    assert!(err.contains("no activation stash"), "unexpected error: {err}");
    // the newest pass is still differentiable
    let latest = engine.submit(&inputs).unwrap().wait().unwrap();
    engine.backward(latest.metrics.epoch, &dy).unwrap();
    // wrong shape / wrong arity are rejected without wedging the engine
    let bad_len: Vec<Vec<f32>> = (0..cfg.system.ranks).map(|_| vec![0.0f32; 3]).collect();
    assert!(engine.backward(latest.metrics.epoch, &bad_len).is_err());
    assert!(engine.backward(latest.metrics.epoch, &dy[..1]).is_err());
    engine.backward(latest.metrics.epoch, &dy).unwrap();
    engine.shutdown();

    // Split mode: backward and update_params are refused
    let backend: Arc<dyn ComputeBackend> = Arc::new(NativeBackend::from_config(&cfg));
    let split =
        MoeEngine::start(cfg.clone(), params.clone(), backend, TaskGraphMode::Split).unwrap();
    let fwd = split.submit(&inputs).unwrap().wait().unwrap();
    assert!(split.backward(fwd.metrics.epoch, &dy).is_err());
    assert!(split.update_params(params.as_ref().clone()).is_err());
}

#[test]
fn trainer_accumulates_windows_and_loss_goes_down() {
    // grad_accum_steps=2: the optimizer applies on every second
    // micro-batch; and the smoothed MSE loss decreases over a short
    // toy regression run (targets = 0, Adam).
    let mut cfg = train_cfg("tiny");
    cfg.set("grad_accum_steps", "2").unwrap();
    cfg.validate().unwrap();
    let params = Arc::new(ModelParams::generate(&cfg, 0x77));
    let inputs = rank_inputs(&cfg, 0x77);
    let targets: Vec<Vec<f32>> = inputs.iter().map(|x| vec![0.0f32; x.len()]).collect();
    let engine = start(&cfg, &params);
    let mut trainer = Trainer::new(engine, Optimizer::adam(5e-3)).unwrap();
    let mut losses = Vec::new();
    for step in 0..12 {
        let report = trainer.train_step(&inputs, &targets).unwrap();
        assert_eq!(report.applied, step % 2 == 1, "step {step}: accumulation window");
        assert!(report.grad_sq_norm > 0.0, "step {step}: zero gradient");
        assert!(report.loss.is_finite());
        losses.push(report.loss);
    }
    assert_eq!(trainer.updates, 6);
    let head: f64 = losses[..4].iter().sum::<f64>() / 4.0;
    let tail: f64 = losses[8..].iter().sum::<f64>() / 4.0;
    assert!(tail < head, "smoothed loss did not decrease: head {head} tail {tail}");
    assert!(losses.last().unwrap() < losses.first().unwrap());
    let em = trainer.engine().metrics();
    assert_eq!(em.backward_passes, 12);
    assert!(em.reverse_bytes > 0);
    let trained = trainer.finish();
    assert_eq!(trained.experts.len(), cfg.model.e);
}
